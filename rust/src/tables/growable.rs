//! Online incremental table growth — the subsystem that removes `Full`
//! as a terminal outcome (WarpCore-style dynamic growth; see PAPERS.md).
//!
//! [`GrowableMap`] wraps any [`ConcurrentMap`] design. When the wrapped
//! table reports `Full`, or its load factor crosses
//! [`GrowthPolicy::trigger_load_factor`], a successor table of TWICE the
//! capacity is allocated and the wrapper enters the *migrating* phase:
//! old-table buckets are moved to the successor in fixed-size batches
//! ([`GrowthPolicy::migration_batch`] buckets per
//! [`ConcurrentMap::drive_migration`] claim) interleaved with foreground
//! traffic, rather than in one stop-the-world copy. The coordinator's
//! persistent shard-affine workers drive migration between operation
//! batches, so growth shares the worker pool instead of stalling it.
//!
//! ## The migration protocol
//!
//! During migration both tables are live, with one rule per operation
//! kind (all serialized per key through one external lock on the key's
//! *old-table primary bucket*, [`Migration::locks`]):
//!
//! * **Queries** are lock-free and read **old-then-new**: a key lives in
//!   the old table until it is moved, and every move inserts into the
//!   successor *before* erasing from the old table, so a key that was
//!   present stays continuously visible.
//! * **Upserts land in the successor.** Any old-table copy is first
//!   moved over (insert-if-unique into the successor, then erase from
//!   old — the same seed-then-erase order), after which the policy is
//!   applied against the successor exactly once. Merge semantics
//!   (`AddAssign`, `Custom`) therefore see the pre-migration value.
//! * **Erases apply to both** tables, old first, under the bucket lock.
//! * **The migrator** claims a bucket range from an atomic cursor, takes
//!   the range's locks, snapshots the live entries whose primary bucket
//!   falls in the range ([`ConcurrentMap::collect_primary_range`]), and
//!   moves each with the same seed-then-erase order.
//!
//! The per-bucket lock means a key never has more than one live copy
//! observable outside a locked window (`count_copies` takes the lock, so
//! stable designs keep their `== 1` invariant across a growth), and
//! erase/upsert races on one key stay linearizable across the pair of
//! tables. When every bucket is migrated and the old table is empty, the
//! wrapper flips back to the *normal* phase over the successor; chained
//! growths (4×, 8×, …) repeat the cycle.
//!
//! ## Shrink / compaction
//!
//! Growth's inverse reuses the same migration machinery verbatim: when
//! load falls below [`GrowthPolicy::shrink_below`] (default off), or on
//! an explicit [`ConcurrentMap::request_shrink`], a successor of HALF
//! the capacity is allocated and the identical migrating phase drains
//! the old table into it — old-then-new reads, seed-then-erase moves,
//! per-old-bucket locks, `count_copies == 1` throughout. Two refusals
//! keep it safe and oscillation-free: a shrink never goes below the
//! capacity the table was built with, and never starts when the live
//! keys would put the ½× successor above the grow watermark (the pump
//! threshold, [`GrowthPolicy::trigger_load_factor`] capped at 0.75) —
//! a shrink that would immediately need to re-grow is refused outright.
//! Keep `shrink_below` under half the grow trigger and the two
//! watermarks can never chase each other.
//!
//! ## Entry lifecycle across a migration
//!
//! When the wrapped design carries lifecycle metadata
//! ([`TableConfig::with_lifecycle`]), growth interacts with expiry in
//! three deliberate ways:
//!
//! * **Expired corpses never migrate.** The migration collectors
//!   ([`ConcurrentMap::collect_primary_range`] and the designs' raw
//!   walks) skip expired entries, so a dead key is never resurrected
//!   into the successor; the foreground move path and the finalize step
//!   physically purge any corpse they encounter so stragglers cannot
//!   pin the old table non-empty.
//! * **A moved entry re-enters the successor immortal** with a zeroed
//!   frequency counter: the seed is a plain insert-if-unique, and the
//!   packed lifecycle code does not travel with it. A live mortal that
//!   migrates therefore stops expiring until its next `upsert_ttl`
//!   re-arms it (TTL-preserving migration is an open ROADMAP item).
//! * **`upsert_ttl` mid-migration lands in the successor** like every
//!   other upsert — the refresh/reclaim semantics apply against the
//!   successor copy after any old-table copy has been moved over.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, RwLock, RwLockReadGuard, RwLockWriteGuard};

use crate::gpusim::{probes, LockArray};

use super::{build_table_with, ConcurrentMap, TableConfig, TableKind, UpsertOp, UpsertResult};

/// When and how a [`GrowableMap`] grows.
#[derive(Clone, Copy, Debug)]
pub struct GrowthPolicy {
    /// Load factor at which a successor is allocated proactively (growth
    /// also starts reactively whenever the wrapped table reports `Full`).
    pub trigger_load_factor: f64,
    /// Old-table buckets migrated per [`ConcurrentMap::drive_migration`]
    /// cursor claim — the fixed migration batch interleaved with
    /// foreground traffic.
    pub migration_batch: usize,
    /// Hard capacity ceiling: a growth that would exceed it is refused
    /// and the table reports `Full` like a fixed-capacity design.
    pub max_capacity: usize,
    /// Low watermark: load factor below which a ½-capacity compaction
    /// starts (checked after erases). `0.0` (the default) disables
    /// automatic shrinking; [`ConcurrentMap::request_shrink`] still
    /// works. Keep this under half of `trigger_load_factor` — the
    /// post-shrink load factor is roughly double the pre-shrink one, so
    /// a larger value could land the successor back at the grow
    /// trigger (the successor-occupancy guard refuses such a shrink
    /// outright, but a well-chosen watermark never hits the guard).
    pub shrink_below: f64,
}

impl Default for GrowthPolicy {
    fn default() -> Self {
        Self {
            trigger_load_factor: 0.85,
            migration_batch: 64,
            max_capacity: usize::MAX / 4,
            shrink_below: 0.0,
        }
    }
}

impl GrowthPolicy {
    /// The pump threshold doubling as the grow watermark a shrink must
    /// respect: the successor load factor above which foreground writers
    /// contribute migration steps, and above which a ½× shrink successor
    /// would be born too full to safely drain the old table into.
    #[inline]
    pub(crate) fn pump_load_factor(&self) -> f64 {
        self.trigger_load_factor.min(0.75)
    }
}

/// Bounded number of chained growth cycles one operation will wait
/// through before reporting `Full` (2^8 = 256× the original capacity —
/// far beyond any workload here; the bound only guards against bugs).
const MAX_GROW_ROUNDS: usize = 8;
/// Backstop on migration-pump iterations inside one blocked operation.
const MAX_PUMPS: usize = 1 << 16;

/// One in-progress old→successor migration.
struct Migration {
    old: Arc<dyn ConcurrentMap>,
    new: Arc<dyn ConcurrentMap>,
    /// One lock per OLD primary bucket: foreground mutators take their
    /// key's lock, the migrator takes its whole claimed range — the
    /// serialization that keeps move/upsert/erase races linearizable.
    locks: LockArray,
    /// Next unclaimed old-table bucket (claims advance by
    /// [`GrowthPolicy::migration_batch`]).
    cursor: AtomicUsize,
    /// Buckets whose migration has COMPLETED (claims count here only
    /// after their range is done; `done == total` gates the phase flip).
    done: AtomicUsize,
    /// Total old-table buckets.
    total: usize,
    /// Times the scan was re-opened because stragglers remained (the
    /// successor was full mid-migration). Lets drivers detect a pinned
    /// migration instead of re-scanning forever.
    resets: AtomicUsize,
}

enum Phase {
    /// Single live table, no growth in progress.
    Normal(Arc<dyn ConcurrentMap>),
    /// Old + successor live simultaneously, migration running.
    Migrating(Arc<Migration>),
}

/// A [`ConcurrentMap`] wrapper that grows online instead of rejecting
/// with `Full`. See the module docs for the migration protocol.
pub struct GrowableMap {
    kind: TableKind,
    base_cfg: TableConfig,
    policy: GrowthPolicy,
    phase: RwLock<Phase>,
    /// Capacity the table was built with — the floor no shrink goes
    /// below (the provisioning the operator asked for).
    initial_capacity: usize,
    /// Growth events (successor allocations) over this table's lifetime.
    grows: AtomicU64,
    /// Shrink events (½-capacity successor allocations).
    shrinks: AtomicU64,
    /// Compactions aborted because a live-load burst saturated the ½×
    /// successor (the migration reversed back into the larger table).
    shrink_aborted: AtomicU64,
    /// Pairs moved old→successor over this table's lifetime.
    migrated: AtomicU64,
    /// Expiry reclaims performed by tables already retired by a phase
    /// flip — their instance counters die with them, so the wrapper
    /// banks the count at the flip ([`ConcurrentMap::swept_expired`]
    /// stays monotone across growths).
    swept_carry: AtomicU64,
}

impl GrowableMap {
    pub fn new(kind: TableKind, cfg: TableConfig, policy: GrowthPolicy) -> Self {
        let initial = build_table_with(kind, cfg.clone());
        let initial_capacity = initial.capacity();
        Self {
            kind,
            base_cfg: cfg,
            policy,
            phase: RwLock::new(Phase::Normal(initial)),
            initial_capacity,
            grows: AtomicU64::new(0),
            shrinks: AtomicU64::new(0),
            shrink_aborted: AtomicU64::new(0),
            migrated: AtomicU64::new(0),
            swept_carry: AtomicU64::new(0),
        }
    }

    pub fn policy(&self) -> GrowthPolicy {
        self.policy
    }

    /// Successor allocations so far.
    pub fn grow_events(&self) -> u64 {
        self.grows.load(Ordering::Relaxed)
    }

    /// Pairs moved old→successor so far.
    pub fn migrated_pairs(&self) -> u64 {
        self.migrated.load(Ordering::Relaxed)
    }

    /// Compactions that reversed because a live-load burst saturated
    /// the ½× successor mid-drain (see [`GrowableMap::finalize`]'s abort
    /// arm): the table returned to its pre-shrink capacity instead of
    /// wedging upserts at `Full`.
    #[cfg(test)] // test-only surface (warpspeed-analyze WS3)
    pub fn shrink_aborts(&self) -> u64 {
        self.shrink_aborted.load(Ordering::Relaxed)
    }

    /// Ordinary operations hold the phase read guard for their whole
    /// duration, so a phase flip never overlaps an in-flight op (a stale
    /// `Normal` writer could otherwise insert into the old table after
    /// its buckets were migrated, stranding the key). Lock poisoning is
    /// ignored: the phase value itself is always consistent.
    fn read_phase(&self) -> RwLockReadGuard<'_, Phase> {
        self.phase.read().unwrap_or_else(|e| e.into_inner())
    }

    fn write_phase(&self) -> RwLockWriteGuard<'_, Phase> {
        self.phase.write().unwrap_or_else(|e| e.into_inner())
    }

    /// Allocate a 2× successor and flip to the migrating phase.
    /// `from_capacity` identifies the table the caller observed full; if
    /// the phase has moved on since (another thread grew, or a migration
    /// is already running) this reports true and the caller simply
    /// retries. Returns false only when [`GrowthPolicy::max_capacity`]
    /// forbids further growth.
    fn begin_grow(&self, from_capacity: usize) -> bool {
        let next_cap = from_capacity.saturating_mul(2);
        if next_cap > self.policy.max_capacity {
            // Refused — unless the phase already moved past the table
            // the caller saw full, in which case a retry may still win.
            let g = self.read_phase();
            return !matches!(&*g, Phase::Normal(t) if t.capacity() == from_capacity);
        }
        // Build the successor BEFORE taking the write lock: allocating
        // and zeroing a table scales with its size and must not stall
        // every concurrent op behind the phase lock. A lost install race
        // just discards the speculative table.
        let mut cfg = self.base_cfg.clone();
        cfg.slots = next_cap;
        let new = build_table_with(self.kind, cfg);
        let mut g = self.write_phase();
        let old = match &*g {
            Phase::Normal(t) => {
                if t.capacity() != from_capacity {
                    return true; // someone already grew — retry
                }
                Arc::clone(t)
            }
            Phase::Migrating(_) => return true, // already growing
        };
        let total = old.num_buckets().max(1);
        *g = Phase::Migrating(Arc::new(Migration {
            old,
            new,
            // Cache-line-padded: the migrator sweeps its claimed range's
            // lock words while foreground ops take single locks on
            // neighbouring words; dense packing would false-share one
            // line between them (ROADMAP perf item).
            locks: LockArray::padded(total),
            cursor: AtomicUsize::new(0),
            done: AtomicUsize::new(0),
            total,
            resets: AtomicUsize::new(0),
        }));
        self.grows.fetch_add(1, Ordering::Relaxed);
        probes::count_grow_event();
        true
    }

    /// Start a growth cycle if the normal-phase load factor has crossed
    /// the trigger. Called after inserts, outside any phase guard.
    fn maybe_trigger_grow(&self) {
        let grow_from = {
            let g = self.read_phase();
            match &*g {
                Phase::Normal(t)
                    if t.len() as f64
                        >= self.policy.trigger_load_factor * t.capacity() as f64 =>
                {
                    Some(t.capacity())
                }
                _ => None,
            }
        };
        if let Some(cap) = grow_from {
            self.begin_grow(cap);
        }
    }

    /// Allocate a ½× successor and flip to the migrating phase — growth's
    /// inverse, reusing the identical migration machinery (the protocol
    /// is direction-agnostic: it drains `old` into `new` whatever their
    /// relative sizes). Refuses (returns false) when:
    /// * the halved capacity would fall below the capacity the table was
    ///   built with (never compact under the requested provisioning);
    /// * the live keys would put the successor at or above the grow
    ///   watermark ([`GrowthPolicy::pump_load_factor`]) — a shrink that
    ///   immediately needs to re-grow is oscillation, and a successor
    ///   born saturated could strand stragglers in the old table;
    /// * the phase moved on from the table the caller observed (another
    ///   thread grew/shrank first, or a migration is already running).
    fn begin_shrink(&self, from_capacity: usize) -> bool {
        let next_cap = from_capacity / 2;
        if next_cap < self.initial_capacity {
            return false;
        }
        // Cheap pre-check outside the phase lock; re-checked under it
        // against the successor actually built.
        {
            let g = self.read_phase();
            match &*g {
                Phase::Normal(t) if t.capacity() == from_capacity => {
                    if t.len() as f64 >= self.policy.pump_load_factor() * next_cap as f64 {
                        return false;
                    }
                }
                _ => return false,
            }
        }
        let mut cfg = self.base_cfg.clone();
        cfg.slots = next_cap;
        let new = build_table_with(self.kind, cfg);
        let mut g = self.write_phase();
        let old = match &*g {
            Phase::Normal(t) if t.capacity() == from_capacity => {
                if t.len() as f64 >= self.policy.pump_load_factor() * new.capacity() as f64 {
                    return false; // load rose since the pre-check
                }
                Arc::clone(t)
            }
            _ => return false, // phase moved on — discard the speculative table
        };
        let total = old.num_buckets().max(1);
        *g = Phase::Migrating(Arc::new(Migration {
            old,
            new,
            locks: LockArray::padded(total),
            cursor: AtomicUsize::new(0),
            done: AtomicUsize::new(0),
            total,
            resets: AtomicUsize::new(0),
        }));
        self.shrinks.fetch_add(1, Ordering::Relaxed);
        probes::count_shrink_event();
        true
    }

    /// Start a compaction if the normal-phase load factor has fallen
    /// below the low watermark. Called after erases, outside any phase
    /// guard (the mirror of [`GrowableMap::maybe_trigger_grow`]).
    fn maybe_trigger_shrink(&self) {
        if self.policy.shrink_below <= 0.0 {
            return;
        }
        let shrink_from = {
            let g = self.read_phase();
            match &*g {
                Phase::Normal(t)
                    if (t.len() as f64) < self.policy.shrink_below * t.capacity() as f64 =>
                {
                    Some(t.capacity())
                }
                _ => None,
            }
        };
        if let Some(cap) = shrink_from {
            self.begin_shrink(cap);
        }
    }

    /// Move `key`'s old-table copy to the successor, under the key's
    /// already-held bucket lock. Seed-then-erase: the successor is
    /// seeded (insert-if-unique, so a fresher successor value wins)
    /// BEFORE the old copy is erased, keeping the key continuously
    /// visible to lock-free old-then-new readers. Returns false when the
    /// successor rejected the seed (saturated) — the old copy stays put
    /// and the caller must bail WITHOUT applying its operation, or it
    /// would leave two live copies and lose the pre-migration value from
    /// merge policies.
    fn move_old_copy(m: &Migration, key: u64) -> bool {
        if let Some(ov) = m.old.query(key) {
            if m.new.upsert(key, ov, &UpsertOp::InsertIfUnique) == UpsertResult::Full {
                return false;
            }
            m.old.erase(key);
        } else {
            // The query is expire-on-read: `None` may hide an expired
            // corpse still occupying its old-table slot. Erase reclaims
            // it physically (reporting false, as for any dead key), so
            // the caller's successor write cannot leave a second
            // physical copy behind — and the corpse never migrates.
            m.old.erase(key);
        }
        true
    }

    /// Upsert during migration, under the key's old-bucket lock: move any
    /// old-table copy over, then apply the policy against the successor
    /// exactly once (with `ttl`'s stamp/refresh semantics when given).
    fn upsert_migrating(
        m: &Migration,
        key: u64,
        val: u64,
        op: &UpsertOp,
        ttl: Option<u64>,
    ) -> UpsertResult {
        let ob = m.old.primary_bucket(key);
        m.locks.lock(ob);
        let r = if Self::move_old_copy(m, key) {
            match ttl {
                Some(t) => m.new.upsert_ttl(key, val, t, op),
                None => m.new.upsert(key, val, op),
            }
        } else {
            // Seed blocked: report Full and let the caller pump/grow.
            UpsertResult::Full
        };
        m.locks.unlock(ob);
        r
    }

    /// Should a foreground writer contribute a migration step right now?
    /// True once the successor's load crosses the pump threshold — the
    /// policy trigger capped at 0.75, so even a near-1.0 trigger leaves
    /// enough successor headroom for the old table to finish draining
    /// before the successor can saturate (no chained growth is possible
    /// until the current migration completes, so a saturated successor
    /// with stragglers left would otherwise wedge the table at `Full`).
    fn successor_needs_pumping(m: &Migration, policy: &GrowthPolicy) -> bool {
        m.new.len() as f64 >= policy.pump_load_factor() * m.new.capacity() as f64
    }

    fn erase_migrating(m: &Migration, key: u64) -> bool {
        let ob = m.old.primary_bucket(key);
        m.locks.lock(ob);
        let hit_old = m.old.erase(key);
        let hit_new = m.new.erase(key);
        m.locks.unlock(ob);
        hit_old || hit_new
    }

    /// Move every entry whose primary bucket is in `[start, end)` to the
    /// successor, under the range's bucket locks. Returns pairs moved.
    fn migrate_range(&self, m: &Migration, start: usize, end: usize) -> usize {
        for b in start..end {
            m.locks.lock(b);
        }
        let mut entries: Vec<(u64, u64)> = Vec::new();
        m.old.collect_primary_range(start..end, &mut entries);
        let mut moved = 0usize;
        for &(k, v) in &entries {
            // Seed-then-erase, same order as the foreground path. A Full
            // seed (successor saturated mid-migration) leaves the entry in
            // the old table; finalize detects the straggler and re-opens
            // the scan after the next chained growth makes room.
            if m.new.upsert(k, v, &UpsertOp::InsertIfUnique) != UpsertResult::Full {
                m.old.erase(k);
                moved += 1;
                probes::count_migrated_pair();
            }
        }
        for b in (start..end).rev() {
            m.locks.unlock(b);
        }
        self.migrated.fetch_add(moved as u64, Ordering::Relaxed);
        moved
    }

    /// Phase flip once every bucket is migrated. A compare-exchange on
    /// `done` elects a single finisher; if stragglers remain in the old
    /// table (successor filled mid-migration) a GROWTH re-opens the scan
    /// — more room arrives via erases or the chained growth after the
    /// flip — while a SHRINK aborts: the ½× successor saturating means a
    /// live-load burst outran the cooldown, and unlike growth there is a
    /// clean escape with capacity to spare, so the migration reverses
    /// and drains the small successor back into the still-larger old
    /// table instead of wedging upserts at `Full` until erases land.
    /// Either way no entry is ever dropped.
    fn finalize(&self, m: &Arc<Migration>) {
        if m
            .done
            .compare_exchange(m.total, usize::MAX, Ordering::AcqRel, Ordering::Relaxed)
            .is_err()
        {
            return;
        }
        // `len` is physical: expired corpses the collectors skipped (no
        // resurrection) still occupy old-table slots and would pin the
        // scan open forever. A full-ring sweep reclaims them before the
        // emptiness check (2× num_buckets covers every design's sweep
        // ring, including Iceberg's combined front+back ring).
        if !m.old.is_empty() && m.old.supports_ttl() {
            m.old.sweep_expired(2 * m.old.num_buckets());
        }
        if m.old.is_empty() {
            let mut g = self.write_phase();
            if matches!(&*g, Phase::Migrating(cur) if Arc::ptr_eq(cur, m)) {
                self.swept_carry
                    .fetch_add(m.old.swept_expired(), Ordering::Relaxed);
                *g = Phase::Normal(Arc::clone(&m.new));
            }
            return;
        }
        if m.new.capacity() < m.old.capacity() {
            // Pinned compaction: reverse it. Swapping the lock domain
            // (fresh locks over the new old-table's buckets) is safe
            // exactly here — the `done` CAS means no migrator claimant
            // is mid-range (claims count into `done` only after their
            // range's locks are released), and the phase write lock
            // excludes every foreground mover (they hold the phase read
            // guard across their whole locked section). A concurrent
            // driver still holding the retired migration's Arc sees its
            // cursor exhausted and its `done` at MAX, and backs out.
            let mut g = self.write_phase();
            if matches!(&*g, Phase::Migrating(cur) if Arc::ptr_eq(cur, m)) {
                let total = m.new.num_buckets().max(1);
                *g = Phase::Migrating(Arc::new(Migration {
                    old: Arc::clone(&m.new),
                    new: Arc::clone(&m.old),
                    locks: LockArray::padded(total),
                    cursor: AtomicUsize::new(0),
                    done: AtomicUsize::new(0),
                    total,
                    resets: AtomicUsize::new(0),
                }));
                self.shrink_aborted.fetch_add(1, Ordering::Relaxed);
            }
            return;
        }
        // Re-open: done must be reset before the cursor so no claimant
        // can finish a re-claimed range while `done` still reads MAX.
        m.resets.fetch_add(1, Ordering::AcqRel);
        m.done.store(0, Ordering::Release);
        m.cursor.store(0, Ordering::Release);
    }

    /// The grow/pump retry loop shared by [`ConcurrentMap::upsert`] and
    /// [`ConcurrentMap::upsert_ttl`] — identical phase handling, with
    /// `ttl` threaded to the live table's TTL path when given.
    fn upsert_with_ttl(
        &self,
        key: u64,
        val: u64,
        op: &UpsertOp,
        ttl: Option<u64>,
    ) -> UpsertResult {
        enum Next {
            Done(UpsertResult, bool),
            Grow(usize),
            Pump,
        }
        let mut grow_rounds = 0usize;
        let mut pumps = 0usize;
        let mut stalled_pumps = 0usize;
        loop {
            let next = {
                let g = self.read_phase();
                match &*g {
                    Phase::Normal(t) => {
                        let r = match ttl {
                            Some(q) => t.upsert_ttl(key, val, q, op),
                            None => t.upsert(key, val, op),
                        };
                        if r == UpsertResult::Full {
                            Next::Grow(t.capacity())
                        } else {
                            Next::Done(r, false)
                        }
                    }
                    Phase::Migrating(m) => {
                        let r = Self::upsert_migrating(m, key, val, op, ttl);
                        if r == UpsertResult::Full {
                            Next::Pump
                        } else {
                            Next::Done(r, Self::successor_needs_pumping(m, &self.policy))
                        }
                    }
                }
            };
            match next {
                Next::Done(r, pump_after) => {
                    if pump_after {
                        self.drive_migration(self.policy.migration_batch);
                    } else if r == UpsertResult::Inserted {
                        self.maybe_trigger_grow();
                    }
                    return r;
                }
                Next::Grow(cap) => {
                    grow_rounds += 1;
                    if grow_rounds > MAX_GROW_ROUNDS || !self.begin_grow(cap) {
                        return UpsertResult::Full;
                    }
                }
                Next::Pump => {
                    // Successor full mid-migration: finish the migration
                    // (then the Normal arm grows again — chained growth).
                    pumps += 1;
                    if self.drive_migration(usize::MAX) > 0 {
                        stalled_pumps = 0;
                    } else {
                        // Either another thread owns the remaining ranges
                        // (transient — wait briefly) or the migration is
                        // pinned at the capacity ceiling (permanent).
                        stalled_pumps += 1;
                        if stalled_pumps > 64 {
                            return UpsertResult::Full;
                        }
                    }
                    if pumps > MAX_PUMPS {
                        return UpsertResult::Full;
                    }
                    std::thread::yield_now();
                }
            }
        }
    }
}

impl ConcurrentMap for GrowableMap {
    fn upsert(&self, key: u64, val: u64, op: &UpsertOp) -> UpsertResult {
        self.upsert_with_ttl(key, val, op, None)
    }

    /// TTL upserts ride the same grow/pump loop: the stamp/refresh lands
    /// on whichever table is live for writes (the successor during a
    /// migration). No-op TTL (plain upsert) when the wrapped design was
    /// built without lifecycle metadata — `supports_ttl` reports that.
    fn upsert_ttl(&self, key: u64, val: u64, ttl_ticks: u64, op: &UpsertOp) -> UpsertResult {
        self.upsert_with_ttl(key, val, op, Some(ttl_ticks))
    }

    fn supports_ttl(&self) -> bool {
        let g = self.read_phase();
        match &*g {
            Phase::Normal(t) => t.supports_ttl(),
            Phase::Migrating(m) => m.new.supports_ttl(),
        }
    }

    /// Sweeps BOTH tables during a migration (each gets the bucket
    /// budget): corpses in the draining old table are exactly the
    /// entries the collectors refuse to move, so sweeping there is what
    /// lets the migration finish without the finalize-time purge.
    fn sweep_expired(&self, max_buckets: usize) -> usize {
        let g = self.read_phase();
        match &*g {
            Phase::Normal(t) => t.sweep_expired(max_buckets),
            Phase::Migrating(m) => {
                m.old.sweep_expired(max_buckets) + m.new.sweep_expired(max_buckets)
            }
        }
    }

    fn swept_expired(&self) -> u64 {
        let carry = self.swept_carry.load(Ordering::Relaxed);
        let g = self.read_phase();
        carry
            + match &*g {
                Phase::Normal(t) => t.swept_expired(),
                Phase::Migrating(m) => m.old.swept_expired() + m.new.swept_expired(),
            }
    }

    /// Old-then-new, like `query`: a key's lifecycle code lives wherever
    /// its entry currently resides. Advisory (no lock) — a concurrent
    /// move can slide the entry between the two probes.
    fn entry_frequency(&self, key: u64) -> Option<u8> {
        let g = self.read_phase();
        match &*g {
            Phase::Normal(t) => t.entry_frequency(key),
            Phase::Migrating(m) => {
                m.old.entry_frequency(key).or_else(|| m.new.entry_frequency(key))
            }
        }
    }

    fn query(&self, key: u64) -> Option<u64> {
        let g = self.read_phase();
        match &*g {
            Phase::Normal(t) => t.query(key),
            // Old-then-new: a key lives in the old table until moved, and
            // moves seed the successor before erasing the old copy.
            Phase::Migrating(m) => m.old.query(key).or_else(|| m.new.query(key)),
        }
    }

    fn erase(&self, key: u64) -> bool {
        let hit = {
            let g = self.read_phase();
            match &*g {
                Phase::Normal(t) => t.erase(key),
                Phase::Migrating(m) => Self::erase_migrating(m, key),
            }
        };
        if hit {
            self.maybe_trigger_shrink();
        }
        hit
    }

    fn upsert_bulk(&self, pairs: &[(u64, u64)], op: &UpsertOp, out: &mut Vec<UpsertResult>) {
        let base = out.len();
        let pump_after = {
            let g = self.read_phase();
            match &*g {
                // Normal phase keeps the wrapped table's native grouped
                // path (one lock + one shared scan per bucket group).
                Phase::Normal(t) => {
                    t.upsert_bulk(pairs, op, out);
                    false
                }
                Phase::Migrating(m) => {
                    out.reserve(pairs.len());
                    for &(k, v) in pairs {
                        out.push(Self::upsert_migrating(m, k, v, op, None));
                    }
                    Self::successor_needs_pumping(m, &self.policy)
                }
            }
        };
        if pump_after {
            self.drive_migration(self.policy.migration_batch);
        }
        // Grow-and-retry every Full in batch order: the scalar path above
        // grows the table and re-applies the op. One batch artifact: an
        // OVERWRITE whose key a LATER op of this same batch already wrote
        // must not be re-applied (it would clobber the newer value); it
        // would have been applied then superseded, so it reports Updated
        // without a side effect. Every other policy retries: the merge
        // policies (AddAssign/Custom) must contribute their merge, and an
        // InsertIfUnique retry against a present key is a harmless no-op.
        for i in base..out.len() {
            if out[i] != UpsertResult::Full {
                continue;
            }
            let j = i - base;
            let (k, v) = pairs[j];
            if matches!(op, UpsertOp::Overwrite)
                && pairs[j + 1..]
                    .iter()
                    .zip(&out[i + 1..])
                    .any(|(&(k2, _), &r2)| k2 == k && r2 != UpsertResult::Full)
            {
                out[i] = UpsertResult::Updated;
                continue;
            }
            out[i] = self.upsert(k, v, op);
        }
        self.maybe_trigger_grow();
    }

    fn query_bulk(&self, keys: &[u64], out: &mut Vec<Option<u64>>) {
        let g = self.read_phase();
        match &*g {
            Phase::Normal(t) => t.query_bulk(keys, out),
            Phase::Migrating(m) => {
                // Old-then-new as two native bulk calls: misses against
                // the old table are re-asked of the successor.
                let base = out.len();
                m.old.query_bulk(keys, out);
                let miss_idx: Vec<usize> =
                    (0..keys.len()).filter(|&i| out[base + i].is_none()).collect();
                if miss_idx.is_empty() {
                    return;
                }
                let miss_keys: Vec<u64> = miss_idx.iter().map(|&i| keys[i]).collect();
                let mut sub: Vec<Option<u64>> = Vec::with_capacity(miss_keys.len());
                m.new.query_bulk(&miss_keys, &mut sub);
                for (j, &i) in miss_idx.iter().enumerate() {
                    out[base + i] = sub[j];
                }
            }
        }
    }

    fn erase_bulk(&self, keys: &[u64], out: &mut Vec<bool>) {
        {
            let g = self.read_phase();
            match &*g {
                Phase::Normal(t) => t.erase_bulk(keys, out),
                Phase::Migrating(m) => {
                    out.reserve(keys.len());
                    for &k in keys {
                        out.push(Self::erase_migrating(m, k));
                    }
                }
            }
        }
        self.maybe_trigger_shrink();
    }

    fn num_buckets(&self) -> usize {
        let g = self.read_phase();
        match &*g {
            Phase::Normal(t) => t.num_buckets(),
            Phase::Migrating(m) => m.new.num_buckets(),
        }
    }

    fn primary_bucket(&self, key: u64) -> usize {
        let g = self.read_phase();
        match &*g {
            Phase::Normal(t) => t.primary_bucket(key),
            Phase::Migrating(m) => m.new.primary_bucket(key),
        }
    }

    /// Capacity of the table currently being filled (the successor while
    /// a migration runs) — this is what grows 2× per cycle.
    fn capacity(&self) -> usize {
        let g = self.read_phase();
        match &*g {
            Phase::Normal(t) => t.capacity(),
            Phase::Migrating(m) => m.new.capacity(),
        }
    }

    fn len(&self) -> usize {
        let g = self.read_phase();
        match &*g {
            Phase::Normal(t) => t.len(),
            Phase::Migrating(m) => m.old.len() + m.new.len(),
        }
    }

    fn device_bytes(&self) -> usize {
        let g = self.read_phase();
        match &*g {
            Phase::Normal(t) => t.device_bytes(),
            // Both tables are resident during a migration — that
            // transient 3× footprint is the price of online growth.
            Phase::Migrating(m) => m.old.device_bytes() + m.new.device_bytes(),
        }
    }

    fn name(&self) -> &'static str {
        let g = self.read_phase();
        match &*g {
            Phase::Normal(t) => t.name(),
            Phase::Migrating(m) => m.new.name(),
        }
    }

    fn is_stable(&self) -> bool {
        let g = self.read_phase();
        match &*g {
            Phase::Normal(t) => t.is_stable(),
            Phase::Migrating(m) => m.new.is_stable(),
        }
    }

    fn fetch_add_in_place(&self, key: u64, v: u64) -> bool {
        let g = self.read_phase();
        match &*g {
            Phase::Normal(t) => t.fetch_add_in_place(key, v),
            Phase::Migrating(m) => {
                // A key mid-migration may move between the in-place read
                // and the add; the bucket lock restores soundness. A
                // blocked move reports false so the caller falls back to
                // its upsert path, which pumps the migration.
                let ob = m.old.primary_bucket(key);
                m.locks.lock(ob);
                let r = Self::move_old_copy(m, key) && m.new.fetch_add_in_place(key, v);
                m.locks.unlock(ob);
                r
            }
        }
    }

    fn fetch_add_f64_in_place(&self, key: u64, v: f64) -> bool {
        let g = self.read_phase();
        match &*g {
            Phase::Normal(t) => t.fetch_add_f64_in_place(key, v),
            Phase::Migrating(m) => {
                let ob = m.old.primary_bucket(key);
                m.locks.lock(ob);
                let r = Self::move_old_copy(m, key) && m.new.fetch_add_f64_in_place(key, v);
                m.locks.unlock(ob);
                r
            }
        }
    }

    fn count_copies(&self, key: u64) -> usize {
        let g = self.read_phase();
        match &*g {
            Phase::Normal(t) => t.count_copies(key),
            Phase::Migrating(m) => {
                // Under the key's bucket lock the seed-then-erase window
                // cannot be observed: the single-copy invariant of stable
                // designs holds across the pair of tables.
                let ob = m.old.primary_bucket(key);
                m.locks.lock(ob);
                let n = m.old.count_copies(key) + m.new.count_copies(key);
                m.locks.unlock(ob);
                n
            }
        }
    }

    fn for_each_entry(&self, f: &mut dyn FnMut(u64, u64)) {
        let g = self.read_phase();
        match &*g {
            Phase::Normal(t) => t.for_each_entry(f),
            Phase::Migrating(m) => {
                m.old.for_each_entry(f);
                m.new.for_each_entry(f);
            }
        }
    }

    /// Forwarded (not defaulted) so the wrapped design's native stripe
    /// walk is reached — the trait default would funnel through this
    /// wrapper's own `for_each_entry` and hide the override.
    fn collect_stripe_range(&self, keep: &dyn Fn(u64) -> bool, out: &mut Vec<(u64, u64)>) {
        let g = self.read_phase();
        match &*g {
            Phase::Normal(t) => t.collect_stripe_range(keep, out),
            Phase::Migrating(m) => {
                m.old.collect_stripe_range(keep, out);
                m.new.collect_stripe_range(keep, out);
            }
        }
    }

    fn can_grow(&self) -> bool {
        true
    }

    fn request_grow(&self) -> bool {
        let cap = {
            let g = self.read_phase();
            match &*g {
                Phase::Normal(t) => Some(t.capacity()),
                Phase::Migrating(_) => None,
            }
        };
        match cap {
            Some(c) => self.begin_grow(c),
            None => true, // already growing
        }
    }

    fn can_shrink(&self) -> bool {
        true
    }

    fn request_shrink(&self) -> bool {
        let cap = {
            let g = self.read_phase();
            match &*g {
                Phase::Normal(t) => Some(t.capacity()),
                // Unlike `request_grow`, a running migration refuses: the
                // caller cannot tell a growth from a shrink, and chained
                // compactions quiesce between halvings anyway.
                Phase::Migrating(_) => None,
            }
        };
        match cap {
            Some(c) => self.begin_shrink(c),
            None => false,
        }
    }

    fn shrink_events(&self) -> u64 {
        self.shrinks.load(Ordering::Relaxed)
    }

    fn migration_in_progress(&self) -> bool {
        matches!(&*self.read_phase(), Phase::Migrating(_))
    }

    fn drive_migration(&self, max_buckets: usize) -> usize {
        let mut moved = 0usize;
        let mut claimed = 0usize;
        let mut resets_seen: Option<usize> = None;
        while claimed < max_buckets {
            let m = {
                let g = self.read_phase();
                match &*g {
                    Phase::Migrating(m) => Arc::clone(m),
                    Phase::Normal(_) => return moved,
                }
            };
            // A scan re-open observed within this call means the
            // successor rejected stragglers: more scanning cannot help
            // until a chained growth makes room, so hand back.
            let resets_now = m.resets.load(Ordering::Acquire);
            match resets_seen {
                None => resets_seen = Some(resets_now),
                Some(r0) if resets_now != r0 => return moved,
                Some(_) => {}
            }
            // One policy batch per claim, clamped to what the caller's
            // `max_buckets` budget still allows.
            let batch = self
                .policy
                .migration_batch
                .max(1)
                .min(max_buckets - claimed);
            let start = m.cursor.fetch_add(batch, Ordering::Relaxed);
            if start >= m.total {
                // Every bucket is claimed; finalize once the in-flight
                // claimants have counted their ranges done.
                if m.done.load(Ordering::Acquire) >= m.total {
                    self.finalize(&m);
                }
                return moved;
            }
            let end = (start + batch).min(m.total);
            moved += self.migrate_range(&m, start, end);
            claimed += end - start;
            let done = m.done.fetch_add(end - start, Ordering::AcqRel) + (end - start);
            if done >= m.total {
                self.finalize(&m);
            }
        }
        moved
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tables::test_support::*;

    fn growable(kind: TableKind, slots: usize, batch: usize) -> GrowableMap {
        GrowableMap::new(
            kind,
            TableConfig::for_kind(kind, slots),
            GrowthPolicy {
                migration_batch: batch,
                ..Default::default()
            },
        )
    }

    /// Drain any in-progress migration from the calling thread.
    fn quiesce(t: &GrowableMap) {
        t.quiesce_migration();
    }

    #[test]
    fn behaves_like_a_plain_table_below_the_trigger() {
        let t = growable(TableKind::P2Meta, 4096, 16);
        check_basic_crud(&t);
        assert_eq!(t.grow_events(), 0, "no growth at low load");
    }

    #[test]
    fn upsert_policies_hold_across_phases() {
        check_upsert_policies(&growable(TableKind::Double, 2048, 16));
    }

    #[test]
    fn oracle_equivalence_with_growth() {
        // The oracle churn stays small, so force growth cycles through a
        // tiny initial table: every op class runs in both phases.
        for kind in [TableKind::Double, TableKind::Chaining, TableKind::Cuckoo] {
            let t = growable(kind, 256, 4);
            check_vs_oracle(&t, 0x6A0 ^ kind as u64);
            quiesce(&t);
        }
    }

    #[test]
    fn grows_past_double_capacity_with_zero_full() {
        for kind in TableKind::CONCURRENT {
            let t = growable(kind, 1024, 8);
            let nominal = t.capacity();
            let ks = keys(nominal * 5 / 2, 0x660 ^ kind as u64);
            for &k in &ks {
                assert_eq!(
                    t.upsert(k, k ^ 7, &UpsertOp::InsertIfUnique),
                    UpsertResult::Inserted,
                    "{kind:?}: growable table rejected an insert"
                );
            }
            quiesce(&t);
            assert!(
                t.capacity() >= nominal * 2,
                "{kind:?}: capacity {} never doubled from {nominal}",
                t.capacity()
            );
            assert!(t.grow_events() >= 1, "{kind:?}");
            assert_eq!(t.len(), ks.len(), "{kind:?}");
            for &k in &ks {
                assert_eq!(t.query(k), Some(k ^ 7), "{kind:?}: key lost across growth");
                assert_eq!(t.count_copies(k), 1, "{kind:?}: key duplicated across growth");
            }
        }
    }

    #[test]
    fn old_then_new_reads_and_erases_mid_migration() {
        let t = growable(TableKind::Double, 2048, 4);
        let ks = keys(1000, 0x662);
        for &k in &ks {
            t.upsert(k, k ^ 1, &UpsertOp::InsertIfUnique);
        }
        assert!(t.request_grow(), "manual grow must start");
        assert!(t.migration_in_progress());
        // Migrate only part of the table: both residencies must answer.
        t.drive_migration(8);
        assert!(t.migration_in_progress(), "batch 4 × 2 claims cannot finish 256 buckets");
        assert!(t.migrated_pairs() > 0, "partial migration moved nothing");
        for &k in &ks {
            assert_eq!(t.query(k), Some(k ^ 1), "key invisible mid-migration");
        }
        // Erases apply to both sides; upserts land in the successor.
        assert!(t.erase(ks[0]));
        assert_eq!(t.query(ks[0]), None);
        assert!(!t.erase(ks[0]), "double erase mid-migration");
        assert_eq!(
            t.upsert(ks[1], 77, &UpsertOp::Overwrite),
            UpsertResult::Updated
        );
        assert_eq!(t.query(ks[1]), Some(77));
        // Merge semantics see the pre-migration value wherever it lives.
        assert_eq!(
            t.upsert(ks[2], 5, &UpsertOp::AddAssign),
            UpsertResult::Updated
        );
        assert_eq!(t.query(ks[2]), Some((ks[2] ^ 1).wrapping_add(5)));
        quiesce(&t);
        assert_eq!(t.query(ks[0]), None);
        assert_eq!(t.query(ks[1]), Some(77));
        assert_eq!(t.len(), ks.len() - 1);
    }

    #[test]
    fn in_place_accumulate_survives_migration() {
        let t = growable(TableKind::P2, 2048, 4);
        let k = keys(1, 0x663)[0];
        t.upsert(k, 10, &UpsertOp::Overwrite);
        t.request_grow();
        assert!(t.fetch_add_in_place(k, 5));
        assert_eq!(t.query(k), Some(15));
        quiesce(&t);
        assert_eq!(t.query(k), Some(15));
        assert_eq!(t.count_copies(k), 1);
    }

    #[test]
    fn bulk_ops_grow_and_stay_in_order() {
        let t = growable(TableKind::IcebergMeta, 512, 4);
        let nominal = t.capacity();
        let ks = keys(nominal * 5 / 2, 0x664);
        let pairs: Vec<(u64, u64)> = ks.iter().map(|&k| (k, k ^ 9)).collect();
        let mut res = Vec::new();
        for chunk in pairs.chunks(128) {
            t.upsert_bulk(chunk, &UpsertOp::InsertIfUnique, &mut res);
        }
        assert_eq!(res.len(), ks.len());
        assert!(
            res.iter().all(|&r| r == UpsertResult::Inserted),
            "bulk insert hit Full on a growable table"
        );
        let mut got = Vec::new();
        t.query_bulk(&ks, &mut got);
        for (i, &k) in ks.iter().enumerate() {
            assert_eq!(got[i], Some(k ^ 9), "bulk query #{i}");
        }
        quiesce(&t);
        assert!(t.capacity() >= nominal * 2);
        let odd: Vec<u64> = ks.iter().copied().skip(1).step_by(2).collect();
        let mut eres = Vec::new();
        t.erase_bulk(&odd, &mut eres);
        assert!(eres.iter().all(|&e| e));
        assert_eq!(t.len(), ks.len() - odd.len());
    }

    #[test]
    fn concurrent_insert_churn_across_growth_keeps_single_copies() {
        // Four threads overfill a stable design ~2.5× its nominal
        // capacity on disjoint key ranges while migration batches run
        // interleaved; no Full, no lost key, no duplicate copy.
        let t = std::sync::Arc::new(growable(TableKind::Chaining, 2048, 8));
        let n_threads = 4;
        let per = (t.capacity() * 5 / 2) / n_threads;
        let all = keys(n_threads * per, 0x665);
        std::thread::scope(|s| {
            for tid in 0..n_threads {
                let t = std::sync::Arc::clone(&t);
                let mine = &all[tid * per..(tid + 1) * per];
                s.spawn(move || {
                    for (i, &k) in mine.iter().enumerate() {
                        assert_eq!(
                            t.upsert(k, k ^ 2, &UpsertOp::InsertIfUnique),
                            UpsertResult::Inserted,
                            "thread {tid} op {i}: Full on a growable table"
                        );
                        if i % 3 == 0 {
                            assert_eq!(t.query(k), Some(k ^ 2));
                        }
                        if i % 64 == 0 {
                            t.drive_migration(2);
                        }
                    }
                    // Own keys: present with exactly one copy, mid-churn.
                    for &k in mine.iter().step_by(17) {
                        assert_eq!(t.count_copies(k), 1, "duplicate mid-growth");
                    }
                });
            }
        });
        assert!(t.quiesce_migration());
        assert!(t.grow_events() >= 1);
        assert_eq!(t.len(), all.len());
        for &k in &all {
            assert_eq!(t.query(k), Some(k ^ 2));
            assert_eq!(t.count_copies(k), 1);
        }
    }

    #[test]
    fn gpusim_migration_counters_track_instance_counters() {
        // Single-threaded growth: every grow event and migrated pair
        // happens on this thread, so the thread-local gpusim counters
        // must agree exactly with the wrapper's instance atomics.
        let _measure = probes::measurement_section();
        probes::set_enabled(true);
        probes::take_grow_events();
        probes::take_migrated_pairs();
        let t = growable(TableKind::Double, 512, 8);
        for &k in &keys(1200, 0x667) {
            t.upsert(k, 1, &UpsertOp::InsertIfUnique);
        }
        quiesce(&t);
        assert!(t.grow_events() >= 1 && t.migrated_pairs() > 0);
        assert_eq!(probes::take_grow_events(), t.grow_events());
        assert_eq!(probes::take_migrated_pairs(), t.migrated_pairs());
    }

    #[test]
    fn shrink_compacts_cooled_table_back_to_initial_capacity() {
        // Fill 2.5× the provisioning (two growth cycles), cool down to a
        // residue, and the low-watermark trigger plus chained
        // request_shrink calls must walk capacity back to exactly the
        // initial provisioning with every survivor intact.
        let t = GrowableMap::new(
            TableKind::Chaining,
            TableConfig::for_kind(TableKind::Chaining, 1024),
            GrowthPolicy {
                migration_batch: 16,
                shrink_below: 0.25,
                ..Default::default()
            },
        );
        let initial = t.capacity();
        let ks = keys(initial * 5 / 2, 0x668);
        for &k in &ks {
            assert_eq!(t.upsert(k, k ^ 5, &UpsertOp::InsertIfUnique), UpsertResult::Inserted);
        }
        quiesce(&t);
        let peak = t.capacity();
        assert!(peak >= initial * 2, "fill never grew: {peak}");
        let (survivors, doomed) = ks.split_at(100);
        for &k in doomed {
            assert!(t.erase(k), "cooldown erase missed");
        }
        assert!(t.shrink_events() >= 1, "low watermark never fired during cooldown");
        quiesce(&t);
        while t.request_shrink() {
            quiesce(&t);
        }
        assert_eq!(t.capacity(), initial, "capacity never returned to the provisioning");
        assert_eq!(t.len(), survivors.len());
        for &k in survivors {
            assert_eq!(t.query(k), Some(k ^ 5), "survivor lost across compaction");
            assert_eq!(t.count_copies(k), 1, "survivor duplicated across compaction");
        }
    }

    #[test]
    fn shrink_refuses_below_initial_capacity_and_above_watermark() {
        let t = growable(TableKind::Double, 1024, 8);
        // Floor: a table at its provisioning must refuse to compact.
        assert!(!t.request_shrink(), "shrink below the initial provisioning");
        assert_eq!(t.shrink_events(), 0);
        // Watermark: grow once, then hold enough keys that the ½×
        // successor would start above the pump threshold — refused.
        let ks = keys(t.capacity() * 3 / 2, 0x669);
        for &k in &ks {
            t.upsert(k, 1, &UpsertOp::InsertIfUnique);
        }
        quiesce(&t);
        let cap = t.capacity();
        assert!(cap >= 2048, "fill never grew");
        assert!(
            t.len() as f64 >= 0.75 * (cap / 2) as f64,
            "test premise: occupancy must exceed the successor watermark"
        );
        assert!(!t.request_shrink(), "shrink into a too-full successor");
        // Cool down below the watermark and the same request succeeds.
        for &k in ks.iter().skip(200) {
            t.erase(k);
        }
        assert!(t.request_shrink(), "cooled table must accept the shrink");
        quiesce(&t);
        assert!(t.capacity() < cap);
        assert_eq!(t.len(), 200);
    }

    #[test]
    fn old_then_new_semantics_hold_mid_shrink() {
        // The growth-migration protocol run in reverse: start a ½×
        // compaction, advance it only partially, and reads/erases/merge
        // upserts must behave exactly like the mid-growth case.
        let t = growable(TableKind::Double, 1024, 4);
        let fill = keys(t.capacity() * 3 / 2, 0x66A);
        for &k in &fill {
            t.upsert(k, 0, &UpsertOp::Overwrite);
        }
        quiesce(&t);
        assert!(t.capacity() >= 2048);
        // Cool down to a small survivor set, then shrink manually.
        let ks: Vec<u64> = fill.iter().copied().take(300).collect();
        for &k in fill.iter().skip(300) {
            t.erase(k);
        }
        for &k in &ks {
            t.upsert(k, k ^ 1, &UpsertOp::Overwrite);
        }
        assert!(t.request_shrink(), "manual shrink must start");
        assert!(t.migration_in_progress());
        t.drive_migration(8);
        assert!(t.migration_in_progress(), "partial drive cannot finish the compaction");
        for &k in &ks {
            assert_eq!(t.query(k), Some(k ^ 1), "key invisible mid-shrink");
        }
        assert!(t.erase(ks[0]));
        assert_eq!(t.query(ks[0]), None);
        assert!(!t.erase(ks[0]), "double erase mid-shrink");
        assert_eq!(t.upsert(ks[1], 77, &UpsertOp::Overwrite), UpsertResult::Updated);
        assert_eq!(t.query(ks[1]), Some(77));
        assert_eq!(t.upsert(ks[2], 5, &UpsertOp::AddAssign), UpsertResult::Updated);
        assert_eq!(t.query(ks[2]), Some((ks[2] ^ 1).wrapping_add(5)));
        quiesce(&t);
        assert_eq!(t.query(ks[0]), None);
        assert_eq!(t.query(ks[1]), Some(77));
        assert_eq!(t.len(), ks.len() - 1);
        for &k in ks.iter().skip(1) {
            assert_eq!(t.count_copies(k), 1, "duplicate after compaction");
        }
    }

    #[test]
    fn concurrent_churn_mid_shrink_keeps_single_copies() {
        // Stable-design invariant under compaction: threads query/erase
        // their own keys while the shrink migration runs interleaved;
        // count_copies == 1 must hold for live keys THROUGHOUT.
        let t = std::sync::Arc::new(GrowableMap::new(
            TableKind::Chaining,
            TableConfig::for_kind(TableKind::Chaining, 2048),
            GrowthPolicy {
                migration_batch: 8,
                shrink_below: 0.3,
                ..Default::default()
            },
        ));
        let fill = keys(t.capacity() * 2, 0x66B);
        for &k in &fill {
            assert_eq!(t.upsert(k, k ^ 4, &UpsertOp::InsertIfUnique), UpsertResult::Inserted);
        }
        assert!(t.quiesce_migration());
        let peak = t.capacity();
        assert!(peak >= 4096);
        // Keep 1/8 of the keys: each of 4 threads owns a disjoint slice
        // of survivors and a disjoint slice of victims; the cooldown
        // crosses the 0.3 watermark mid-churn and starts the compaction
        // under the concurrent erases/queries.
        let n_threads = 4;
        let per = fill.len() / n_threads;
        std::thread::scope(|s| {
            for tid in 0..n_threads {
                let t = std::sync::Arc::clone(&t);
                let mine = &fill[tid * per..(tid + 1) * per];
                s.spawn(move || {
                    let (keep, kill) = mine.split_at(mine.len() / 8);
                    for (i, &k) in kill.iter().enumerate() {
                        assert!(t.erase(k), "thread {tid} erase {i}");
                        if i % 32 == 0 {
                            t.drive_migration(2);
                        }
                        if i % 64 == 0 {
                            for &probe in keep.iter().step_by(29) {
                                assert_eq!(t.count_copies(probe), 1, "duplicate mid-shrink");
                                assert_eq!(t.query(probe), Some(probe ^ 4), "lost mid-shrink");
                            }
                        }
                    }
                });
            }
        });
        assert!(t.quiesce_migration());
        assert!(t.shrink_events() >= 1);
        for slice in fill.chunks(per) {
            let (keep, kill) = slice.split_at(slice.len() / 8);
            for &k in keep {
                assert_eq!(t.query(k), Some(k ^ 4));
                assert_eq!(t.count_copies(k), 1);
            }
            for &k in kill.iter().step_by(13) {
                assert_eq!(t.count_copies(k), 0, "erased-key residue");
            }
        }
    }

    #[test]
    fn insert_burst_mid_shrink_aborts_the_compaction_instead_of_rejecting() {
        // A shrink is mid-drain when live load comes back: the ½×
        // successor saturates before the old table empties. The
        // compaction must REVERSE (drain the successor back into the
        // larger table) rather than wedge upserts at Full until erases
        // land — zero Full across the whole burst.
        let all = keys(1536 + 1200, 0x66D);
        let (fill, burst) = all.split_at(1536);
        let t = growable(TableKind::Double, 1024, 256);
        for &k in fill {
            t.upsert(k, k ^ 3, &UpsertOp::Overwrite);
        }
        quiesce(&t);
        assert_eq!(t.capacity(), 2048, "fill must grow exactly once");
        // Cool to 300 survivors and start the compaction toward 1024.
        let (keep, kill) = fill.split_at(300);
        for &k in kill {
            t.erase(k);
        }
        assert!(t.request_shrink(), "cooled table must accept the shrink");
        assert!(t.migration_in_progress());
        // The burst: 1200 fresh inserts. Live keys (300 + 1200) exceed
        // the 1024-slot successor, so the drain MUST block and abort;
        // with batch 256 the first pump claims the whole old table and
        // hits the saturation deterministically.
        for (i, &k) in burst.iter().enumerate() {
            assert_eq!(
                t.upsert(k, k ^ 4, &UpsertOp::InsertIfUnique),
                UpsertResult::Inserted,
                "burst insert {i} rejected mid-shrink"
            );
        }
        assert!(t.shrink_aborts() >= 1, "saturated compaction never reversed");
        quiesce(&t);
        assert_eq!(t.capacity(), 2048, "abort must restore the pre-shrink capacity");
        assert_eq!(t.len(), keep.len() + burst.len());
        for &k in keep.iter().step_by(11) {
            assert_eq!(t.query(k), Some(k ^ 3), "survivor lost across the abort");
            assert_eq!(t.count_copies(k), 1);
        }
        for &k in burst.iter().step_by(17) {
            assert_eq!(t.query(k), Some(k ^ 4), "burst key lost across the abort");
            assert_eq!(t.count_copies(k), 1);
        }
    }

    #[test]
    fn gpusim_shrink_counter_tracks_instance_counter() {
        let _measure = probes::measurement_section();
        probes::set_enabled(true);
        probes::take_shrink_events();
        let t = growable(TableKind::Double, 1024, 8);
        let ks = keys(t.capacity() * 3 / 2, 0x66C);
        for &k in &ks {
            t.upsert(k, 1, &UpsertOp::InsertIfUnique);
        }
        quiesce(&t);
        for &k in ks.iter().skip(64) {
            t.erase(k);
        }
        assert!(t.request_shrink());
        quiesce(&t);
        assert!(t.shrink_events() >= 1);
        assert_eq!(probes::take_shrink_events(), t.shrink_events());
        probes::take_grow_events();
        probes::take_migrated_pairs();
    }

    #[test]
    fn capacity_ceiling_restores_full() {
        let t = GrowableMap::new(
            TableKind::Double,
            TableConfig::for_kind(TableKind::Double, 256),
            GrowthPolicy {
                migration_batch: 8,
                max_capacity: 512,
                ..Default::default()
            },
        );
        let ks = keys(2048, 0x666);
        let mut full = 0;
        for &k in &ks {
            if t.upsert(k, 1, &UpsertOp::InsertIfUnique) == UpsertResult::Full {
                full += 1;
            }
        }
        quiesce(&t);
        assert!(t.capacity() <= 512, "ceiling breached: {}", t.capacity());
        assert!(full > 0, "a capped table must eventually reject");
        assert!(t.grow_events() >= 1, "growth below the ceiling must run");
    }

    use crate::tables::lifecycle::LifecycleConfig;

    fn growable_ttl(
        kind: TableKind,
        slots: usize,
        batch: usize,
        cfg: &LifecycleConfig,
    ) -> GrowableMap {
        GrowableMap::new(
            kind,
            TableConfig::for_kind(kind, slots).with_lifecycle(cfg.clone()),
            GrowthPolicy {
                migration_batch: batch,
                ..Default::default()
            },
        )
    }

    #[test]
    fn ttl_surface_forwards_through_the_wrapper() {
        let cfg = LifecycleConfig::new(4);
        let t = growable_ttl(TableKind::Double, 4096, 16, &cfg);
        check_ttl_semantics(&t, &cfg);
        assert_eq!(t.grow_events(), 0, "TTL churn below the trigger must not grow");
        // Without lifecycle the wrapper reports no TTL support and
        // upsert_ttl degrades to a plain upsert.
        let plain = growable(TableKind::Double, 4096, 16);
        assert!(!plain.supports_ttl());
        let k = keys(1, 0x6B0)[0];
        assert_eq!(
            plain.upsert_ttl(k, 9, 2 * cfg.quantum, &UpsertOp::InsertIfUnique),
            UpsertResult::Inserted
        );
        cfg.clock.advance(32 * cfg.quantum);
        assert_eq!(plain.query(k), Some(9), "no-lifecycle entries are immortal");
    }

    #[test]
    fn sweep_forwards_and_matches_the_oracle() {
        let cfg = LifecycleConfig::new(1);
        let t = growable_ttl(TableKind::P2Meta, 4096, 16, &cfg);
        check_sweep_vs_oracle(&t, &cfg);
    }

    #[test]
    fn expiry_churn_across_growth_never_resurrects() {
        // Mortals expire BEFORE the growth starts: the migration must
        // neither move the corpses into the successor (no resurrection)
        // nor let them pin the old table non-empty (finalize purges).
        for kind in [TableKind::Double, TableKind::Chaining, TableKind::IcebergMeta] {
            let cfg = LifecycleConfig::new(1);
            let t = growable_ttl(kind, 256, 4, &cfg);
            let all = keys(t.capacity() * 5 / 2, 0x6B1 ^ kind as u64);
            let (mortal, rest) = all.split_at(64);
            let (immortal, wave) = rest.split_at(64);
            for &k in mortal {
                assert_eq!(
                    t.upsert_ttl(k, k ^ 1, 2, &UpsertOp::InsertIfUnique),
                    UpsertResult::Inserted,
                    "{kind:?}"
                );
            }
            for &k in immortal {
                t.upsert(k, k ^ 2, &UpsertOp::InsertIfUnique);
            }
            cfg.clock.advance(3); // every mortal is now a corpse
            for &k in wave {
                assert_eq!(
                    t.upsert(k, k ^ 3, &UpsertOp::InsertIfUnique),
                    UpsertResult::Inserted,
                    "{kind:?}: growable table rejected an insert"
                );
            }
            quiesce(&t);
            assert!(t.grow_events() >= 1, "{kind:?}: wave never forced a growth");
            for &k in mortal {
                assert_eq!(t.query(k), None, "{kind:?}: expired key resurrected");
                assert_eq!(
                    t.count_copies(k),
                    0,
                    "{kind:?}: corpse migrated or left behind"
                );
            }
            assert!(
                t.swept_expired() >= mortal.len() as u64,
                "{kind:?}: sweep carry lost reclaims across the flip ({} < {})",
                t.swept_expired(),
                mortal.len()
            );
            for &k in immortal {
                assert_eq!(t.query(k), Some(k ^ 2), "{kind:?}: immortal lost");
                assert_eq!(t.count_copies(k), 1, "{kind:?}");
            }
            for &k in wave {
                assert_eq!(t.query(k), Some(k ^ 3), "{kind:?}: wave key lost");
                assert_eq!(t.count_copies(k), 1, "{kind:?}");
            }
            assert_eq!(t.len(), immortal.len() + wave.len(), "{kind:?}");
        }
    }

    #[test]
    fn ttl_ops_mid_migration_land_in_the_successor() {
        let cfg = LifecycleConfig::new(4);
        let t = growable_ttl(TableKind::Double, 2048, 4, &cfg);
        let ks = keys(1000, 0x6B2);
        for &k in &ks[..997] {
            t.upsert(k, k ^ 1, &UpsertOp::InsertIfUnique);
        }
        // One pre-made corpse: expired before the migration starts.
        t.upsert_ttl(ks[997], 7, 2 * cfg.quantum, &UpsertOp::InsertIfUnique);
        cfg.clock.advance(3 * cfg.quantum);
        assert!(t.request_grow(), "manual grow must start");
        t.drive_migration(8);
        assert!(t.migration_in_progress());
        // Refresh an existing immortal with a TTL: Updated, and the
        // entry (now in the successor) expires on schedule.
        assert_eq!(
            t.upsert_ttl(ks[0], 11, 2 * cfg.quantum, &UpsertOp::Overwrite),
            UpsertResult::Updated
        );
        assert_eq!(t.query(ks[0]), Some(11));
        assert_eq!(t.count_copies(ks[0]), 1, "refresh left two copies");
        // Fresh mortal insert mid-migration.
        assert_eq!(
            t.upsert_ttl(ks[998], 13, 2 * cfg.quantum, &UpsertOp::InsertIfUnique),
            UpsertResult::Inserted
        );
        // Upsert over the pre-made corpse mid-migration: the move path
        // purges the old-table corpse, so the reclaim is a single copy.
        assert_eq!(
            t.upsert_ttl(ks[997], 21, 2 * cfg.quantum, &UpsertOp::InsertIfUnique),
            UpsertResult::Inserted,
            "corpse must reclaim as a fresh insert"
        );
        assert_eq!(t.query(ks[997]), Some(21));
        assert_eq!(t.count_copies(ks[997]), 1, "corpse copy left in the old table");
        quiesce(&t);
        cfg.clock.advance(3 * cfg.quantum);
        for &k in [ks[0], ks[997], ks[998]].iter() {
            assert_eq!(t.query(k), None, "successor TTL not honored");
        }
        // The wrapper's sweep reaches the (now single) live table.
        let reclaimed = t.sweep_expired(2 * t.num_buckets());
        assert_eq!(reclaimed, 3, "sweep missed successor corpses");
    }

    #[test]
    fn migration_drops_ttl_as_documented() {
        // A live mortal that migrates re-enters the successor immortal
        // (module docs; TTL-preserving migration is a ROADMAP item).
        // This test pins the documented semantics.
        let cfg = LifecycleConfig::new(4);
        let t = growable_ttl(TableKind::P2, 1024, 16, &cfg);
        let k = keys(1, 0x6B3)[0];
        t.upsert_ttl(k, 5, 2 * cfg.quantum, &UpsertOp::InsertIfUnique);
        assert!(t.request_grow());
        quiesce(&t);
        cfg.clock.advance(32 * cfg.quantum);
        assert_eq!(
            t.query(k),
            Some(5),
            "migrated entries are immortal until re-armed"
        );
        // Re-arming restores expiry.
        t.upsert_ttl(k, 5, 2 * cfg.quantum, &UpsertOp::Overwrite);
        cfg.clock.advance(3 * cfg.quantum);
        assert_eq!(t.query(k), None);
    }
}
