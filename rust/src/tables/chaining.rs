//! ChainingHT — closed addressing with per-bucket linked lists (paper §5).
//!
//! Each chain node spans exactly one 128-byte cache line: 7 KV pairs
//! (112 bytes) + a next pointer + padding. Nodes are allocated from the
//! Gallatin-style slab allocator ([`crate::alloc::SlabAllocator`]); the
//! bucket-head array is sized so chains have expected length 1 at the
//! nominal capacity.
//!
//! Concurrency: inserts/erases lock the bucket; queries are lock-free —
//! new nodes are *prepended* with a release store of the head pointer so
//! a reader that observes the new head sees a fully initialized node.
//! Erased pairs are reset to EMPTY inside their node (slots are reused by
//! later inserts) but nodes are never unlinked while the table is live:
//! safe memory reclamation without epochs is impossible for lock-free
//! readers, and the GPU implementations (SlabHash, GELHash) make the same
//! choice. This is also why the paper's caching workload shows the
//! chaining table's footprint growing (§6.6: 10% cache grew to 28%).
//!
//! Bulk operations are native: a batch is grouped by chain bucket and a
//! SINGLE chain walk ([`ChainingHt::walk_group`]) serves every op of the
//! group — hits, the shared free-pair list, and (for upserts) fresh-node
//! prepends whose remaining pairs feed the rest of the group — under one
//! bucket-lock acquisition. Pointer-chasing is chaining's dominant cost,
//! so the per-group walk is the analog of the warp-cooperative chain
//! traversal in SlabHash-style bulk kernels.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use crate::alloc::{SlabAllocator, NIL};
use crate::gpusim::mem::{is_user_key, SimMem, EMPTY};
use crate::gpusim::race::RaceEvent;
use crate::gpusim::LockArray;
use crate::hash::hash1;

use super::lifecycle::LifecycleSlots;
use super::{ConcurrencyMode, ConcurrentMap, TableConfig, UpsertOp, UpsertResult};

/// KV pairs per chain node (7 pairs + next pointer = one cache line).
pub const NODE_PAIRS: usize = 7;
/// u64 slots per node: 14 pair slots, 1 pad, 1 next pointer.
const NODE_SLOTS: usize = 16;
/// Offset of the next pointer within a node.
const NEXT_OFF: usize = 15;

pub struct ChainingHt {
    heads: SimMem,
    nodes: SlabAllocator,
    locks: LockArray,
    num_buckets: usize,
    nominal_slots: usize,
    mode: ConcurrencyMode,
    hook: std::sync::Arc<dyn crate::gpusim::race::RaceHook>,
    live: AtomicU64,
    /// TTL + frequency codes, one per node pair. Modeled COLOCATED: the
    /// 7 per-pair codes of a node pack into the node's 8-byte pad word
    /// (slot 14), which sits inside the very cache line every chain walk
    /// already loads — code reads/bumps cost zero extra lines.
    life: Option<LifecycleSlots>,
    sweep_cursor: AtomicUsize,
    swept: AtomicU64,
}

impl ChainingHt {
    pub fn new(cfg: TableConfig) -> Self {
        // Expected chain length 1: one bucket per NODE_PAIRS keys.
        let nb = (cfg.slots.div_ceil(NODE_PAIRS))
            .next_power_of_two()
            .max(1);
        // Arena slack ×3 for chain-length skew plus growth under churn
        // (the paper's caching workload grows a 10% chaining table to 28%).
        let arena_nodes = nb * 3 + 16;
        let life = cfg
            .lifecycle
            .clone()
            .map(|lc| LifecycleSlots::colocated(lc, arena_nodes * NODE_PAIRS));
        Self {
            heads: SimMem::new(nb),
            nodes: SlabAllocator::new(arena_nodes, NODE_SLOTS),
            locks: LockArray::new(nb),
            num_buckets: nb,
            nominal_slots: cfg.slots,
            mode: cfg.mode,
            hook: cfg.hook,
            live: AtomicU64::new(0),
            life,
            sweep_cursor: AtomicUsize::new(0),
            swept: AtomicU64::new(0),
        }
    }

    /// Flat lifecycle index of a node pair (node ids start at 1).
    #[inline(always)]
    fn lifeslot(&self, node: u64, pair: usize) -> usize {
        (node as usize - 1) * NODE_PAIRS + pair
    }

    /// Lifecycle index recovered from a pair's key slot index (the raw
    /// chain walks hand out `kidx`, not (node, pair)).
    #[inline(always)]
    fn lifeslot_of_kidx(&self, kidx: usize) -> usize {
        (kidx / NODE_SLOTS) * NODE_PAIRS + (kidx % NODE_SLOTS) / 2
    }

    #[inline]
    fn is_expired(&self, node: u64, pair: usize) -> bool {
        self.life
            .as_ref()
            .is_some_and(|l| l.is_expired_at(self.lifeslot(node, pair)))
    }

    /// Query-hit bookkeeping: bump frequency; `false` = expired (miss).
    #[inline]
    fn hit_live(&self, node: u64, pair: usize) -> bool {
        match &self.life {
            Some(l) => l.on_hit(self.lifeslot(node, pair)),
            None => true,
        }
    }

    #[inline]
    fn stamp_fresh(&self, node: u64, pair: usize, ttl: Option<u64>) {
        if let Some(l) = &self.life {
            l.fresh(self.lifeslot(node, pair), ttl);
        }
    }

    /// Reclaim an expired pair in place as a fresh insert of `val`.
    #[inline]
    fn reclaim_if_expired(&self, node: u64, pair: usize, val: u64, ttl: Option<u64>) -> bool {
        if !self.is_expired(node, pair) {
            return false;
        }
        self.nodes
            .mem()
            .store_release(self.pair_kidx(node, pair) + 1, val);
        self.stamp_fresh(node, pair, ttl);
        true
    }

    #[inline(always)]
    fn bucket_of(&self, key: u64) -> usize {
        (hash1(key) & (self.num_buckets as u64 - 1)) as usize
    }

    #[inline(always)]
    fn pair_kidx(&self, node: u64, pair: usize) -> usize {
        self.nodes.base_slot(node) + pair * 2
    }

    #[inline(always)]
    fn next_of(&self, node: u64, strong: bool) -> u64 {
        self.nodes
            .mem()
            .load(self.nodes.base_slot(node) + NEXT_OFF, strong)
    }

    /// Walk the chain for `key`. Returns the node+pair when found, and the
    /// first free (EMPTY) pair encountered anywhere in the chain.
    fn walk(
        &self,
        bucket: usize,
        key: u64,
        strong: bool,
    ) -> (Option<(u64, usize, u64)>, Option<(u64, usize)>) {
        let mem = self.nodes.mem();
        let mut node = self.heads.load(bucket, strong);
        let mut free = None;
        while node != NIL {
            for p in 0..NODE_PAIRS {
                let kidx = self.pair_kidx(node, p);
                let k = mem.load(kidx, strong);
                if k == key {
                    let v = mem.load(kidx + 1, strong);
                    return (Some((node, p, v)), free);
                }
                if k == EMPTY && free.is_none() {
                    free = Some((node, p));
                }
            }
            node = self.next_of(node, strong);
        }
        (None, free)
    }

    /// One chain walk serving a whole bucket group: `found` is cleared
    /// and filled parallel to `keys` with each key's (node, pair,
    /// value-at-scan) — duplicate keys each receive the hit — and every
    /// free (EMPTY) pair is returned in chain order. The chain's cache
    /// lines are walked ONCE regardless of group size, where the scalar
    /// [`ChainingHt::walk`] would re-walk per op.
    fn walk_group(
        &self,
        bucket: usize,
        keys: &[u64],
        strong: bool,
        found: &mut Vec<Option<(u64, usize, u64)>>,
    ) -> Vec<(u64, u16)> {
        found.clear();
        found.resize(keys.len(), None);
        let mem = self.nodes.mem();
        let mut free = Vec::new();
        let mut node = self.heads.load(bucket, strong);
        while node != NIL {
            for p in 0..NODE_PAIRS {
                let kidx = self.pair_kidx(node, p);
                let k = mem.load(kidx, strong);
                if k == EMPTY {
                    free.push((node, p as u16));
                } else if is_user_key(k) {
                    // Single pass over the group's keys; the value is
                    // loaded lazily on the first match so misses keep
                    // the scalar walk's probe footprint.
                    let mut v: Option<u64> = None;
                    for (i, &q) in keys.iter().enumerate() {
                        if q == k {
                            let vv = *v.get_or_insert_with(|| mem.load(kidx + 1, strong));
                            found[i] = Some((node, p, vv));
                        }
                    }
                }
            }
            node = self.next_of(node, strong);
        }
        free
    }

    /// Allocate, initialize, and release-publish a fresh head node
    /// holding `key → val` (the node contents happen-before any reader
    /// that observes the new head). Returns the node id, or `None` when
    /// the arena is exhausted. Caller holds the bucket lock in locking
    /// mode and accounts the insert's own hook events.
    fn prepend_node(&self, bucket: usize, key: u64, val: u64, strong: bool) -> Option<u64> {
        let mem = self.nodes.mem();
        let node = self.nodes.alloc()?;
        let base = self.nodes.base_slot(node);
        for i in 0..NODE_SLOTS {
            mem.store_relaxed(base + i, 0);
        }
        mem.store_relaxed(base + 1, val);
        mem.store_relaxed(base, key);
        let old_head = self.heads.load(bucket, strong);
        mem.store_relaxed(base + NEXT_OFF, old_head);
        // Release-publish the head: the node contents (key, value,
        // next) happen-before any reader that observes the new head.
        self.heads.store_release(bucket, node);
        self.live.fetch_add(1, Ordering::Relaxed);
        Some(node)
    }

    /// Raw snapshot walk of bucket `b`'s chain: the callback receives
    /// every pair slot's key index and raw key value (EMPTY included).
    /// The single traversal all quiesced/raw scans share —
    /// `for_each_entry`, `count_copies`, and the migration iterator.
    fn walk_chain_raw(&self, b: usize, f: &mut dyn FnMut(usize, u64)) {
        let mem = self.nodes.mem();
        let mut node = self.heads.snapshot_raw(b);
        while node != NIL {
            for p in 0..NODE_PAIRS {
                let kidx = self.pair_kidx(node, p);
                f(kidx, mem.snapshot_raw(kidx));
            }
            node = mem.snapshot_raw(self.nodes.base_slot(node) + NEXT_OFF);
        }
    }

    fn apply_existing(&self, node: u64, pair: usize, old_v: u64, val: u64, op: &UpsertOp) {
        let mem = self.nodes.mem();
        let vidx = self.pair_kidx(node, pair) + 1;
        match op.merge(old_v, val) {
            Some(newv) => {
                if newv != old_v {
                    mem.store_release(vidx, newv);
                }
            }
            None => match op {
                UpsertOp::AddAssign => {
                    mem.fetch_add(vidx, val);
                }
                UpsertOp::AddAssignF64 => {
                    mem.fetch_add_f64(vidx, f64::from_bits(val));
                }
                _ => unreachable!(),
            },
        }
    }
}

impl ChainingHt {
    /// Scalar upsert body, shared by `upsert` / `upsert_ttl`.
    fn upsert_with_ttl(&self, key: u64, val: u64, op: &UpsertOp, ttl: Option<u64>) -> UpsertResult {
        debug_assert!(is_user_key(key));
        let bucket = self.bucket_of(key);
        if self.mode.locking() {
            self.locks.lock(bucket);
        }
        let strong = self.mode.strong();
        let mem = self.nodes.mem();
        let res = 'done: {
            let (found, free) = self.walk(bucket, key, strong);
            if let Some((node, pair, old_v)) = found {
                if self.reclaim_if_expired(node, pair, val, ttl) {
                    break 'done UpsertResult::Inserted;
                }
                self.apply_existing(node, pair, old_v, val, op);
                if ttl.is_some() {
                    if let Some(l) = &self.life {
                        l.refresh(self.lifeslot(node, pair), ttl);
                    }
                }
                break 'done UpsertResult::Updated;
            }
            self.hook
                .on_event(RaceEvent::BeforeClaim { key, bucket });
            if let Some((node, pair)) = free {
                // Publish into the free pair: value first, key release —
                // lock-free readers never see a half-written pair.
                let kidx = self.pair_kidx(node, pair);
                mem.store_relaxed(kidx + 1, val);
                mem.store_release(kidx, key);
                self.stamp_fresh(node, pair, ttl);
                self.live.fetch_add(1, Ordering::Relaxed);
                break 'done UpsertResult::Inserted;
            }
            // Chain full: allocate and prepend a fresh node.
            self.hook
                .on_event(RaceEvent::PrimaryFullMovingOn { key, bucket });
            match self.prepend_node(bucket, key, val, strong) {
                Some(node) => {
                    self.stamp_fresh(node, 0, ttl);
                    UpsertResult::Inserted
                }
                None => UpsertResult::Full,
            }
        };
        if self.mode.locking() {
            self.locks.unlock(bucket);
        }
        res
    }

    /// Tombstone a corpse iff still present AND still expired under the
    /// bucket lock (sweep-vs-writer race guard).
    fn erase_expired(&self, key: u64) -> bool {
        let bucket = self.bucket_of(key);
        if self.mode.locking() {
            self.locks.lock(bucket);
        }
        let strong = self.mode.strong();
        let mut killed = false;
        if let (Some((node, pair, _)), _) = self.walk(bucket, key, strong) {
            if self.is_expired(node, pair) {
                if let Some(l) = &self.life {
                    l.clear(self.lifeslot(node, pair));
                }
                self.nodes
                    .mem()
                    .store_release(self.pair_kidx(node, pair), EMPTY);
                self.live.fetch_sub(1, Ordering::Relaxed);
                self.hook.on_event(RaceEvent::AfterDelete { key, bucket });
                killed = true;
            }
        }
        if self.mode.locking() {
            self.locks.unlock(bucket);
        }
        killed
    }
}

impl ConcurrentMap for ChainingHt {
    fn upsert(&self, key: u64, val: u64, op: &UpsertOp) -> UpsertResult {
        self.upsert_with_ttl(key, val, op, None)
    }

    fn upsert_ttl(&self, key: u64, val: u64, ttl_ticks: u64, op: &UpsertOp) -> UpsertResult {
        if self.life.is_none() {
            return self.upsert(key, val, op);
        }
        self.upsert_with_ttl(key, val, op, Some(ttl_ticks))
    }

    fn query(&self, key: u64) -> Option<u64> {
        let bucket = self.bucket_of(key);
        let (found, _) = self.walk(bucket, key, self.mode.strong());
        found.and_then(|(node, pair, v)| self.hit_live(node, pair).then_some(v))
    }

    fn erase(&self, key: u64) -> bool {
        let bucket = self.bucket_of(key);
        if self.mode.locking() {
            self.locks.lock(bucket);
        }
        let strong = self.mode.strong();
        let (found, _) = self.walk(bucket, key, strong);
        let hit = if let Some((node, pair, _)) = found {
            let was_live = !self.is_expired(node, pair);
            if let Some(l) = &self.life {
                l.clear(self.lifeslot(node, pair));
            }
            self.nodes
                .mem()
                .store_release(self.pair_kidx(node, pair), EMPTY);
            self.live.fetch_sub(1, Ordering::Relaxed);
            self.hook.on_event(RaceEvent::AfterDelete { key, bucket });
            was_live
        } else {
            false
        };
        if self.mode.locking() {
            self.locks.unlock(bucket);
        }
        hit
    }

    /// Bucket-grouped bulk upsert: one bucket lock and ONE chain walk
    /// ([`ChainingHt::walk_group`]) serve every op that hashes to the
    /// bucket. Inserts consume the walk's shared free-pair list in chain
    /// order (exactly the slots the scalar loop would pick); when the
    /// list runs dry a fresh node is prepended and its remaining pairs
    /// feed the rest of the group.
    fn upsert_bulk(&self, pairs_in: &[(u64, u64)], op: &UpsertOp, out: &mut Vec<UpsertResult>) {
        let base = out.len();
        out.resize(base + pairs_in.len(), UpsertResult::Full);
        let mut slots = super::SlotWriter::new(&mut out[base..]);
        let buckets: Vec<usize> = pairs_in.iter().map(|&(k, _)| self.bucket_of(k)).collect();
        let locking = self.mode.locking();
        let strong = self.mode.strong();
        let mem = self.nodes.mem();
        let mut found: Vec<Option<(u64, usize, u64)>> = Vec::new();
        let mut group_keys: Vec<u64> = Vec::new();
        super::for_each_bucket_group(&buckets, |b, group| {
            if locking {
                self.locks.lock(b);
            }
            group_keys.clear();
            group_keys.extend(group.iter().map(|&i| pairs_in[i as usize].0));
            let mut free = self.walk_group(b, &group_keys, strong, &mut found);
            let mut free_cursor = 0usize;
            // Keys this group placed (location known for later dups) and
            // keys the exhausted arena rejected.
            let mut local: Vec<(u64, u64, usize)> = Vec::new();
            let mut full_keys: Vec<u64> = Vec::new();
            for (j, &i) in group.iter().enumerate() {
                let (k, v) = pairs_in[i as usize];
                debug_assert!(is_user_key(k));
                let loc = local
                    .iter()
                    .find(|&&(lk, _, _)| lk == k)
                    .map(|&(_, n, p)| (n, p))
                    .or_else(|| found[j].map(|(n, p, _)| (n, p)));
                if let Some((node, pair)) = loc {
                    if self.reclaim_if_expired(node, pair, v, None) {
                        slots.set(i as usize, UpsertResult::Inserted);
                        continue;
                    }
                    // Present (at scan time or placed by this group):
                    // merge with a FRESH value read — earlier ops of this
                    // very group may have updated it since the walk.
                    let vidx = self.pair_kidx(node, pair) + 1;
                    let old = mem.load(vidx, strong);
                    self.apply_existing(node, pair, old, v, op);
                    slots.set(i as usize, UpsertResult::Updated);
                    continue;
                }
                if full_keys.contains(&k) {
                    slots.set(i as usize, UpsertResult::Full);
                    continue;
                }
                self.hook.on_event(RaceEvent::BeforeClaim { key: k, bucket: b });
                if let Some(&(node, pair)) = free.get(free_cursor) {
                    free_cursor += 1;
                    let (node, pair) = (node, pair as usize);
                    // Publish into the free pair: value first, key
                    // release — lock-free readers never see a
                    // half-written pair.
                    let kidx = self.pair_kidx(node, pair);
                    mem.store_relaxed(kidx + 1, v);
                    mem.store_release(kidx, k);
                    self.stamp_fresh(node, pair, None);
                    self.live.fetch_add(1, Ordering::Relaxed);
                    local.push((k, node, pair));
                    slots.set(i as usize, UpsertResult::Inserted);
                    continue;
                }
                // Free list dry: prepend a fresh node, hand its remaining
                // pairs to the rest of the group (the scalar walk would
                // find exactly these, newest node first).
                self.hook
                    .on_event(RaceEvent::PrimaryFullMovingOn { key: k, bucket: b });
                match self.prepend_node(b, k, v, strong) {
                    Some(node) => {
                        self.stamp_fresh(node, 0, None);
                        for p in 1..NODE_PAIRS {
                            free.push((node, p as u16));
                        }
                        local.push((k, node, 0));
                        slots.set(i as usize, UpsertResult::Inserted);
                    }
                    None => {
                        slots.set(i as usize, UpsertResult::Full);
                        full_keys.push(k);
                    }
                }
            }
            if locking {
                self.locks.unlock(b);
            }
        });
        slots.finish("ChainingHT::upsert_bulk");
    }

    /// Bucket-grouped bulk query: lock-free, one chain walk per group.
    fn query_bulk(&self, keys_in: &[u64], out: &mut Vec<Option<u64>>) {
        let base = out.len();
        out.resize(base + keys_in.len(), None);
        let mut slots = super::SlotWriter::new(&mut out[base..]);
        let buckets: Vec<usize> = keys_in.iter().map(|&k| self.bucket_of(k)).collect();
        let strong = self.mode.strong();
        let mut found: Vec<Option<(u64, usize, u64)>> = Vec::new();
        let mut group_keys: Vec<u64> = Vec::new();
        super::for_each_bucket_group(&buckets, |b, group| {
            group_keys.clear();
            group_keys.extend(group.iter().map(|&i| keys_in[i as usize]));
            self.walk_group(b, &group_keys, strong, &mut found);
            for (j, &i) in group.iter().enumerate() {
                slots.set(
                    i as usize,
                    found[j].and_then(|(node, pair, v)| {
                        self.hit_live(node, pair).then_some(v)
                    }),
                );
            }
        });
        slots.finish("ChainingHT::query_bulk");
    }

    /// Bucket-grouped bulk erase: one bucket lock and one chain walk per
    /// group. Duplicate keys match the scalar loop: the first occurrence
    /// settles the slot, later ones report false.
    fn erase_bulk(&self, keys_in: &[u64], out: &mut Vec<bool>) {
        let base = out.len();
        out.resize(base + keys_in.len(), false);
        let mut slots = super::SlotWriter::new(&mut out[base..]);
        let buckets: Vec<usize> = keys_in.iter().map(|&k| self.bucket_of(k)).collect();
        let locking = self.mode.locking();
        let strong = self.mode.strong();
        let mut found: Vec<Option<(u64, usize, u64)>> = Vec::new();
        let mut group_keys: Vec<u64> = Vec::new();
        super::for_each_bucket_group(&buckets, |b, group| {
            if locking {
                self.locks.lock(b);
            }
            group_keys.clear();
            group_keys.extend(group.iter().map(|&i| keys_in[i as usize]));
            self.walk_group(b, &group_keys, strong, &mut found);
            let mut done: Vec<u64> = Vec::new();
            for (j, &i) in group.iter().enumerate() {
                let k = keys_in[i as usize];
                if done.contains(&k) {
                    // First occurrence already erased it (or proved it
                    // absent); a scalar rescan would miss either way.
                    slots.set(i as usize, false);
                    continue;
                }
                done.push(k);
                slots.set(i as usize, match found[j] {
                    Some((node, pair, _)) => {
                        let was_live = !self.is_expired(node, pair);
                        if let Some(l) = &self.life {
                            l.clear(self.lifeslot(node, pair));
                        }
                        self.nodes
                            .mem()
                            .store_release(self.pair_kidx(node, pair), EMPTY);
                        self.live.fetch_sub(1, Ordering::Relaxed);
                        self.hook.on_event(RaceEvent::AfterDelete { key: k, bucket: b });
                        was_live
                    }
                    None => false,
                });
            }
            if locking {
                self.locks.unlock(b);
            }
        });
        slots.finish("ChainingHT::erase_bulk");
    }

    fn num_buckets(&self) -> usize {
        self.num_buckets
    }

    fn primary_bucket(&self, key: u64) -> usize {
        self.bucket_of(key)
    }

    fn capacity(&self) -> usize {
        self.nominal_slots
    }

    fn len(&self) -> usize {
        self.live.load(Ordering::Relaxed) as usize
    }

    fn device_bytes(&self) -> usize {
        // Heads + locks + *live* nodes (the Gallatin pool reservation is
        // shared infrastructure; the paper's §6.1 numbers count the
        // memory the table actually allocates — pointer overhead and
        // chain-length skew are what make chaining expensive).
        self.heads.bytes()
            + self.locks.bytes()
            + self.nodes.live() as usize * NODE_SLOTS * 8
            + self.life.as_ref().map_or(0, |l| l.device_bytes())
    }

    fn name(&self) -> &'static str {
        "ChainingHT"
    }

    fn is_stable(&self) -> bool {
        true
    }

    fn fetch_add_in_place(&self, key: u64, v: u64) -> bool {
        let bucket = self.bucket_of(key);
        let (found, _) = self.walk(bucket, key, self.mode.strong());
        match found {
            Some((node, pair, _)) => {
                if self.is_expired(node, pair) {
                    return false;
                }
                self.nodes.mem().fetch_add(self.pair_kidx(node, pair) + 1, v);
                true
            }
            None => false,
        }
    }

    fn fetch_add_f64_in_place(&self, key: u64, v: f64) -> bool {
        let bucket = self.bucket_of(key);
        let (found, _) = self.walk(bucket, key, self.mode.strong());
        match found {
            Some((node, pair, _)) => {
                if self.is_expired(node, pair) {
                    return false;
                }
                self.nodes
                    .mem()
                    .fetch_add_f64(self.pair_kidx(node, pair) + 1, v);
                true
            }
            None => false,
        }
    }

    fn for_each_entry(&self, f: &mut dyn FnMut(u64, u64)) {
        let mem = self.nodes.mem();
        for b in 0..self.num_buckets {
            self.walk_chain_raw(b, &mut |kidx, k| {
                if is_user_key(k)
                    && !self
                        .life
                        .as_ref()
                        .is_some_and(|l| l.is_expired_at(self.lifeslot_of_kidx(kidx)))
                {
                    f(k, mem.snapshot_raw(kidx + 1));
                }
            });
        }
    }

    fn count_copies(&self, key: u64) -> usize {
        let mut n = 0;
        for b in 0..self.num_buckets {
            self.walk_chain_raw(b, &mut |_, k| {
                if k == key {
                    n += 1;
                }
            });
        }
        n
    }

    /// Native migration iterator: chaining stores every entry in its
    /// primary bucket's chain, so a range snapshot is a direct walk of
    /// the range's chains — no full-table filter like the trait default.
    fn collect_primary_range(&self, range: std::ops::Range<usize>, out: &mut Vec<(u64, u64)>) {
        let mem = self.nodes.mem();
        for b in range {
            self.walk_chain_raw(b, &mut |kidx, k| {
                // Expired corpses are never migrated (no resurrection).
                if is_user_key(k)
                    && !self
                        .life
                        .as_ref()
                        .is_some_and(|l| l.is_expired_at(self.lifeslot_of_kidx(kidx)))
                {
                    out.push((k, mem.snapshot_raw(kidx + 1)));
                }
            });
        }
    }

    /// Native routing-stripe iterator: stripes are hash-scattered, so
    /// the walk still visits every chain, but it is ONE raw pass with
    /// the routing predicate applied inline — where the trait default
    /// routes each pair through `for_each_entry`'s per-entry virtual
    /// callback before the filter even runs. Split/merge stripe claims
    /// pay this scan once per claim, which made chaining the design
    /// where the default's constant factor hurt most (ROADMAP perf
    /// item).
    fn collect_stripe_range(&self, keep: &dyn Fn(u64) -> bool, out: &mut Vec<(u64, u64)>) {
        let mem = self.nodes.mem();
        for b in 0..self.num_buckets {
            self.walk_chain_raw(b, &mut |kidx, k| {
                // Expired corpses are never migrated (no resurrection).
                if is_user_key(k)
                    && keep(k)
                    && !self
                        .life
                        .as_ref()
                        .is_some_and(|l| l.is_expired_at(self.lifeslot_of_kidx(kidx)))
                {
                    out.push((k, mem.snapshot_raw(kidx + 1)));
                }
            });
        }
    }

    fn supports_ttl(&self) -> bool {
        self.life.is_some()
    }

    fn sweep_expired(&self, max_buckets: usize) -> usize {
        let Some(l) = &self.life else { return 0 };
        let nb = self.num_buckets;
        let n = max_buckets.min(nb);
        if n == 0 {
            return 0;
        }
        let start = self.sweep_cursor.fetch_add(n, Ordering::Relaxed) % nb;
        let mut victims: Vec<u64> = Vec::new();
        for off in 0..n {
            let b = (start + off) % nb;
            self.walk_chain_raw(b, &mut |kidx, k| {
                if is_user_key(k) && l.is_expired_at(self.lifeslot_of_kidx(kidx)) {
                    victims.push(k);
                }
            });
        }
        let mut reclaimed = 0;
        for k in victims {
            if self.erase_expired(k) {
                reclaimed += 1;
            }
        }
        self.swept.fetch_add(reclaimed as u64, Ordering::Relaxed);
        reclaimed
    }

    fn swept_expired(&self) -> u64 {
        self.swept.load(Ordering::Relaxed)
    }

    fn entry_frequency(&self, key: u64) -> Option<u8> {
        let l = self.life.as_ref()?;
        let bucket = self.bucket_of(key);
        let (found, _) = self.walk(bucket, key, self.mode.strong());
        let (node, pair, _) = found?;
        let ls = self.lifeslot(node, pair);
        (!l.is_expired_at(ls)).then(|| l.freq_at(ls))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tables::test_support::*;

    fn table(slots: usize) -> ChainingHt {
        ChainingHt::new(TableConfig::new(slots).with_geometry(NODE_PAIRS, 4))
    }

    fn table_ttl(slots: usize, cfg: &crate::tables::LifecycleConfig) -> ChainingHt {
        ChainingHt::new(
            TableConfig::new(slots)
                .with_geometry(NODE_PAIRS, 4)
                .with_lifecycle(cfg.clone()),
        )
    }

    #[test]
    fn basic_crud() {
        check_basic_crud(&table(2048));
    }

    #[test]
    fn fills_past_nominal() {
        // Chaining can exceed its nominal capacity by growing chains.
        check_fill_to(&table(4096), 1.0);
    }

    #[test]
    fn upsert_policies() {
        check_upsert_policies(&table(2048));
    }

    #[test]
    fn aging_churn() {
        check_aging_churn(&table(4096), 40);
    }

    #[test]
    fn concurrent_no_duplicates() {
        check_concurrent_no_duplicates(std::sync::Arc::new(table(8192)));
    }

    #[test]
    fn concurrent_mixed() {
        check_concurrent_mixed(std::sync::Arc::new(table(8192)));
    }

    #[test]
    fn in_place_accumulate() {
        check_fetch_add_in_place(&table(2048));
    }

    #[test]
    fn oracle_equivalence() {
        check_vs_oracle(&table(4096), 0x51);
    }

    #[test]
    fn bulk_matches_scalar_twin() {
        check_bulk_parity(&table(2048), &table(2048), 0x54);
    }

    #[test]
    fn bulk_parity_on_tiny_table_with_long_chains() {
        // 16 buckets for a 96-key universe: chains run several nodes
        // deep, so the grouped walk must serve hits, frees, and node
        // prepends from one pass and still match the scalar twin.
        check_bulk_parity(&table(64), &table(64), 0x55);
    }

    #[test]
    fn bulk_concurrent_no_duplicates() {
        check_bulk_concurrent_no_duplicates(std::sync::Arc::new(table(8192)));
    }

    #[test]
    fn ttl_semantics() {
        let cfg = crate::tables::LifecycleConfig::new(4);
        check_ttl_semantics(&table_ttl(2048, &cfg), &cfg);
    }

    #[test]
    fn sweep_matches_expiry_oracle() {
        let cfg = crate::tables::LifecycleConfig::new(1);
        check_sweep_vs_oracle(&table_ttl(2048, &cfg), &cfg);
    }

    #[test]
    fn bulk_ttl_parity() {
        let cfg = crate::tables::LifecycleConfig::new(2);
        check_bulk_ttl_parity(&table_ttl(2048, &cfg), &table_ttl(2048, &cfg), &cfg, 0x56);
    }

    #[test]
    fn expired_pairs_recycle_without_new_nodes() {
        // Mortal keys in deep chains: once expired, upserts of NEW keys
        // cannot reuse those pairs (different key, chain walk finds no
        // free slot) but a sweep turns corpses into EMPTY pairs that the
        // next insert wave reuses without allocating nodes.
        let cfg = crate::tables::LifecycleConfig::new(1);
        let t = table_ttl(64, &cfg);
        let ks = keys(60, 0x57);
        for &k in &ks {
            assert_ne!(
                t.upsert_ttl(k, 1, 2, &UpsertOp::InsertIfUnique),
                UpsertResult::Full
            );
        }
        let live_nodes = t.nodes.live();
        cfg.clock.advance(2);
        let mut reclaimed = 0;
        for _ in 0..(2 * t.num_buckets()).div_ceil(8) {
            reclaimed += t.sweep_expired(8);
        }
        assert_eq!(reclaimed, ks.len(), "all mortals must be swept");
        assert_eq!(t.nodes.live(), live_nodes, "sweep never unlinks nodes");
        // Reinsert a fresh wave into the recycled pairs: no node growth.
        let ks2 = keys(60, 0x58);
        for &k in &ks2 {
            assert_ne!(
                t.upsert(k, 2, &UpsertOp::InsertIfUnique),
                UpsertResult::Full
            );
        }
        assert_eq!(t.nodes.live(), live_nodes, "swept pairs must be reused");
    }

    #[test]
    fn lifecycle_off_is_free() {
        let t = table(1024);
        assert!(!t.supports_ttl());
        assert_eq!(t.sweep_expired(64), 0);
        assert_eq!(t.entry_frequency(42), None);
    }

    #[test]
    fn chains_grow_and_slots_recycle() {
        let t = table(64);
        // Force many keys into few buckets to grow chains.
        let ks = keys(60, 0xC4A1);
        for &k in &ks {
            assert_ne!(
                t.upsert(k, 1, &UpsertOp::InsertIfUnique),
                UpsertResult::Full
            );
        }
        let live_nodes = t.nodes.live();
        assert!(live_nodes > 0);
        // Erase everything; slots become reusable without freeing nodes.
        for &k in &ks {
            assert!(t.erase(k));
        }
        assert_eq!(t.nodes.live(), live_nodes, "nodes are not unlinked");
        // Reinsert reuses freed pairs: node count must not grow.
        for &k in &ks {
            t.upsert(k, 2, &UpsertOp::InsertIfUnique);
        }
        assert_eq!(t.nodes.live(), live_nodes, "erased pairs must be reused");
    }
}
