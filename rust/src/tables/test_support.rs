//! Shared behavioural checks run against every table design. Each table's
//! unit tests call into these so all designs are held to the same
//! contract (CRUD semantics, load-factor targets, aging, concurrency,
//! upsert policies, oracle equivalence).

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread;

use super::lifecycle::{LifecycleConfig, FREQ_MAX, TTL_HORIZON_QUANTA};
use super::{ConcurrentMap, UpsertOp, UpsertResult};
use crate::prng::Xoshiro256pp;

/// Deterministic distinct user keys.
pub fn keys(n: usize, seed: u64) -> Vec<u64> {
    let mut rng = Xoshiro256pp::new(seed);
    let mut set = std::collections::HashSet::with_capacity(n);
    let mut v = Vec::with_capacity(n);
    while v.len() < n {
        let k = rng.next_u64();
        if crate::gpusim::mem::is_user_key(k) && set.insert(k) {
            v.push(k);
        }
    }
    v
}

pub fn check_basic_crud(t: &dyn ConcurrentMap) {
    let ks = keys(100, 0xBA51C);
    for (i, &k) in ks.iter().enumerate() {
        assert_eq!(t.query(k), None, "fresh table must miss");
        assert_eq!(
            t.upsert(k, i as u64, &UpsertOp::InsertIfUnique),
            UpsertResult::Inserted
        );
    }
    assert_eq!(t.len(), 100);
    for (i, &k) in ks.iter().enumerate() {
        assert_eq!(t.query(k), Some(i as u64), "query after insert");
    }
    // Re-upsert must not duplicate.
    for &k in &ks {
        assert_eq!(
            t.upsert(k, 999, &UpsertOp::InsertIfUnique),
            UpsertResult::Updated
        );
        assert_eq!(t.count_copies(k), 1, "duplicate copies of {k:#x}");
    }
    assert_eq!(t.len(), 100);
    // Erase half.
    for &k in ks.iter().step_by(2) {
        assert!(t.erase(k), "erase present key");
        assert_eq!(t.query(k), None, "query after erase");
        assert!(!t.erase(k), "double erase");
    }
    assert_eq!(t.len(), 50);
    for (i, &k) in ks.iter().enumerate() {
        if i % 2 == 1 {
            assert_eq!(t.query(k), Some(i as u64), "survivor intact");
        }
    }
}

pub fn check_fill_to(t: &dyn ConcurrentMap, load_factor: f64) {
    let target = (t.capacity() as f64 * load_factor) as usize;
    let ks = keys(target, 0xF111);
    let mut inserted = 0;
    for &k in &ks {
        match t.upsert(k, k ^ 1, &UpsertOp::InsertIfUnique) {
            UpsertResult::Inserted => inserted += 1,
            UpsertResult::Updated => panic!("distinct key reported updated"),
            UpsertResult::Full => {}
        }
    }
    assert!(
        inserted as f64 >= target as f64 * 0.98,
        "{}: only {inserted}/{target} inserted at lf={load_factor}",
        t.name()
    );
    // All inserted keys must be queryable.
    let mut found = 0;
    for &k in &ks {
        if t.query(k) == Some(k ^ 1) {
            found += 1;
        }
    }
    assert_eq!(found, inserted, "{}: lost keys", t.name());
}

pub fn check_upsert_policies(t: &dyn ConcurrentMap) {
    let k = keys(1, 0x9999)[0];
    assert_eq!(t.upsert(k, 10, &UpsertOp::Overwrite), UpsertResult::Inserted);
    assert_eq!(t.upsert(k, 20, &UpsertOp::Overwrite), UpsertResult::Updated);
    assert_eq!(t.query(k), Some(20));
    assert_eq!(
        t.upsert(k, 5, &UpsertOp::InsertIfUnique),
        UpsertResult::Updated
    );
    assert_eq!(t.query(k), Some(20), "insert-if-unique must not clobber");
    assert_eq!(t.upsert(k, 22, &UpsertOp::AddAssign), UpsertResult::Updated);
    assert_eq!(t.query(k), Some(42));
    let maxer = |a: u64, b: u64| a.max(b);
    assert_eq!(
        t.upsert(k, 7, &UpsertOp::Custom(&maxer)),
        UpsertResult::Updated
    );
    assert_eq!(t.query(k), Some(42));
    assert_eq!(
        t.upsert(k, 100, &UpsertOp::Custom(&maxer)),
        UpsertResult::Updated
    );
    assert_eq!(t.query(k), Some(100));
    // AddAssign on a missing key inserts the value.
    let k2 = keys(2, 0x9999)[1];
    assert_eq!(t.upsert(k2, 3, &UpsertOp::AddAssign), UpsertResult::Inserted);
    assert_eq!(t.query(k2), Some(3));
    // f64 accumulate.
    let k3 = keys(3, 0x9A9A)[2];
    assert_eq!(
        t.upsert(k3, 1.5f64.to_bits(), &UpsertOp::AddAssignF64),
        UpsertResult::Inserted
    );
    assert_eq!(
        t.upsert(k3, 2.25f64.to_bits(), &UpsertOp::AddAssignF64),
        UpsertResult::Updated
    );
    assert_eq!(f64::from_bits(t.query(k3).unwrap()), 3.75);
}

/// Churn the table near 85% load, verifying no key is lost or duplicated.
pub fn check_aging_churn(t: &dyn ConcurrentMap, iterations: usize) {
    let cap = t.capacity();
    let fill = (cap as f64 * 0.85) as usize;
    let slice = (cap / 100).max(4);
    let universe = keys(fill + (iterations + 2) * slice + 2, 0xA9E);
    let mut next = 0usize;
    let mut oldest = 0usize;
    for _ in 0..fill {
        assert_eq!(
            t.upsert(universe[next], next as u64, &UpsertOp::InsertIfUnique),
            UpsertResult::Inserted
        );
        next += 1;
    }
    for it in 0..iterations {
        for _ in 0..slice {
            let r = t.upsert(universe[next], next as u64, &UpsertOp::InsertIfUnique);
            assert!(
                r != UpsertResult::Updated,
                "{}: fresh key reported updated at iteration {it}",
                t.name()
            );
            if r == UpsertResult::Inserted {
                next += 1;
            }
        }
        for _ in 0..slice {
            assert!(
                t.erase(universe[oldest]),
                "{}: aged key vanished at iteration {it}",
                t.name()
            );
            oldest += 1;
        }
        // Negative queries must stay correct while aged.
        let probe_key = universe[next + slice + 1];
        assert_eq!(t.query(probe_key), None);
        // Live keys stay present and unique.
        let mid = (oldest + next) / 2;
        assert_eq!(t.query(universe[mid]), Some(mid as u64));
        assert_eq!(t.count_copies(universe[mid]), 1);
    }
}

/// Hammer the same key set from several threads; every key must end up
/// with exactly one copy (the §4.1 guarantee).
pub fn check_concurrent_no_duplicates(t: Arc<dyn ConcurrentMap>) {
    let ks = Arc::new(keys(512, 0xC0C0));
    let n_threads = 4;
    let mut hs = vec![];
    for tid in 0..n_threads {
        let t = Arc::clone(&t);
        let ks = Arc::clone(&ks);
        hs.push(thread::spawn(move || {
            let mut order: Vec<usize> = (0..ks.len()).collect();
            let mut rng = Xoshiro256pp::new(tid as u64);
            rng.shuffle(&mut order);
            for i in order {
                t.upsert(ks[i], i as u64, &UpsertOp::InsertIfUnique);
            }
        }));
    }
    for h in hs {
        h.join().unwrap();
    }
    for (i, &k) in ks.iter().enumerate() {
        assert_eq!(t.count_copies(k), 1, "key {i} duplicated");
        assert_eq!(t.query(k), Some(i as u64));
    }
    assert_eq!(t.len(), ks.len());
}

/// Concurrent inserts + erases + queries on disjoint key ranges per
/// thread; per-range effects must match a sequential run.
pub fn check_concurrent_mixed(t: Arc<dyn ConcurrentMap>) {
    let per_thread = 256;
    let n_threads = 4;
    let all = keys(per_thread * n_threads, 0x1213);
    let all = Arc::new(all);
    let misses = Arc::new(AtomicUsize::new(0));
    let mut hs = vec![];
    for tid in 0..n_threads {
        let t = Arc::clone(&t);
        let all = Arc::clone(&all);
        let misses = Arc::clone(&misses);
        hs.push(thread::spawn(move || {
            let my = &all[tid * per_thread..(tid + 1) * per_thread];
            for (i, &k) in my.iter().enumerate() {
                assert_eq!(
                    t.upsert(k, i as u64, &UpsertOp::InsertIfUnique),
                    UpsertResult::Inserted
                );
            }
            // Interleave queries on other threads' ranges (may hit or miss
            // depending on progress — must never return a wrong value).
            let other = &all[((tid + 1) % n_threads) * per_thread..];
            for (i, &k) in other[..per_thread].iter().enumerate() {
                match t.query(k) {
                    Some(v) => assert_eq!(v, i as u64, "wrong value under concurrency"),
                    None => {
                        misses.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            // Erase the odd half of my range.
            for (i, &k) in my.iter().enumerate() {
                if i % 2 == 1 {
                    assert!(t.erase(k));
                }
            }
        }));
    }
    for h in hs {
        h.join().unwrap();
    }
    for tid in 0..n_threads {
        let my = &all[tid * per_thread..(tid + 1) * per_thread];
        for (i, &k) in my.iter().enumerate() {
            if i % 2 == 1 {
                assert_eq!(t.query(k), None);
            } else {
                assert_eq!(t.query(k), Some(i as u64));
                assert_eq!(t.count_copies(k), 1);
            }
        }
    }
}

pub fn check_fetch_add_in_place(t: &dyn ConcurrentMap) {
    if !t.is_stable() {
        assert!(!t.fetch_add_in_place(123, 1));
        return;
    }
    let k = keys(1, 0xFAFA)[0];
    assert!(!t.fetch_add_in_place(k, 5), "absent key");
    t.upsert(k, 10, &UpsertOp::Overwrite);
    assert!(t.fetch_add_in_place(k, 5));
    assert_eq!(t.query(k), Some(15));
    t.upsert(k, 0f64.to_bits(), &UpsertOp::Overwrite);
    assert!(t.fetch_add_f64_in_place(k, 2.5));
    assert!(t.fetch_add_f64_in_place(k, 0.5));
    assert_eq!(f64::from_bits(t.query(k).unwrap()), 3.0);
}

/// Drive `bulk_t` through the bulk APIs and `scalar_t` (a fresh table of
/// the same design/size) through the scalar APIs with the same stream of
/// homogeneous runs — the shape the coordinator produces after
/// run-splitting — over a small universe so batches are full of
/// duplicate keys. Every per-op result must match, and both tables must
/// agree with a `HashMap` oracle at the end.
pub fn check_bulk_parity(bulk_t: &dyn ConcurrentMap, scalar_t: &dyn ConcurrentMap, seed: u64) {
    let mut rng = Xoshiro256pp::new(seed);
    let universe = keys(96, seed ^ 0xB17C);
    let mut oracle: HashMap<u64, u64> = HashMap::new();
    let draw = |rng: &mut Xoshiro256pp| universe[rng.next_below(96) as usize];
    for round in 0..80 {
        let len = 1 + rng.next_below(48) as usize;
        match rng.next_below(4) {
            0 | 1 => {
                let accumulate = rng.next_below(2) == 0;
                let op = if accumulate {
                    UpsertOp::AddAssign
                } else {
                    UpsertOp::Overwrite
                };
                let pairs: Vec<(u64, u64)> = (0..len)
                    .map(|_| (draw(&mut rng), rng.next_below(1_000)))
                    .collect();
                let mut bulk_res = Vec::new();
                bulk_t.upsert_bulk(&pairs, &op, &mut bulk_res);
                assert_eq!(bulk_res.len(), pairs.len());
                for (i, &(k, v)) in pairs.iter().enumerate() {
                    let want = scalar_t.upsert(k, v, &op);
                    assert_eq!(
                        bulk_res[i], want,
                        "{}: round {round} upsert #{i} key {k:#x}",
                        bulk_t.name()
                    );
                    if accumulate {
                        oracle
                            .entry(k)
                            .and_modify(|x| *x = x.wrapping_add(v))
                            .or_insert(v);
                    } else {
                        oracle.insert(k, v);
                    }
                }
            }
            2 => {
                let ks: Vec<u64> = (0..len).map(|_| draw(&mut rng)).collect();
                let mut bulk_res = Vec::new();
                bulk_t.query_bulk(&ks, &mut bulk_res);
                assert_eq!(bulk_res.len(), ks.len());
                for (i, &k) in ks.iter().enumerate() {
                    assert_eq!(
                        bulk_res[i],
                        oracle.get(&k).copied(),
                        "{}: round {round} query #{i} key {k:#x}",
                        bulk_t.name()
                    );
                    assert_eq!(bulk_res[i], scalar_t.query(k));
                }
            }
            _ => {
                let ks: Vec<u64> = (0..len).map(|_| draw(&mut rng)).collect();
                let mut bulk_res = Vec::new();
                bulk_t.erase_bulk(&ks, &mut bulk_res);
                assert_eq!(bulk_res.len(), ks.len());
                for (i, &k) in ks.iter().enumerate() {
                    let want = scalar_t.erase(k);
                    assert_eq!(
                        bulk_res[i], want,
                        "{}: round {round} erase #{i} key {k:#x}",
                        bulk_t.name()
                    );
                    assert_eq!(bulk_res[i], oracle.remove(&k).is_some());
                }
            }
        }
    }
    // Final state audit: bulk table ≡ oracle ≡ scalar twin.
    assert_eq!(bulk_t.len(), oracle.len(), "{}", bulk_t.name());
    for &k in &universe {
        assert_eq!(bulk_t.query(k), oracle.get(&k).copied(), "{}", bulk_t.name());
        assert!(bulk_t.count_copies(k) <= 1, "{}: duplicate {k:#x}", bulk_t.name());
    }
}

/// Hammer the same key set through `upsert_bulk` from several threads;
/// every key must end up with exactly one copy (the §4.1 guarantee must
/// survive the grouped fast path's shared free-slot claims).
pub fn check_bulk_concurrent_no_duplicates(t: Arc<dyn ConcurrentMap>) {
    let ks = Arc::new(keys(512, 0xB07C));
    let n_threads = 4;
    let mut hs = vec![];
    for tid in 0..n_threads {
        let t = Arc::clone(&t);
        let ks = Arc::clone(&ks);
        hs.push(thread::spawn(move || {
            let mut order: Vec<usize> = (0..ks.len()).collect();
            let mut rng = Xoshiro256pp::new(tid as u64);
            rng.shuffle(&mut order);
            let pairs: Vec<(u64, u64)> = order.iter().map(|&i| (ks[i], i as u64)).collect();
            let mut res = Vec::new();
            for chunk in pairs.chunks(64) {
                t.upsert_bulk(chunk, &UpsertOp::InsertIfUnique, &mut res);
            }
        }));
    }
    for h in hs {
        h.join().unwrap();
    }
    for (i, &k) in ks.iter().enumerate() {
        assert_eq!(t.count_copies(k), 1, "key {i} duplicated");
        assert_eq!(t.query(k), Some(i as u64));
    }
    assert_eq!(t.len(), ks.len());
}

/// The full TTL/frequency contract, run against any design built with a
/// [`LifecycleConfig`]: expire-on-read (scalar + bulk), reclaim-in-place
/// on upsert-over-corpse with the single-copy invariant, erase-on-expired
/// reporting absent, TTL refresh preserving frequency, counter
/// saturation, and beyond-horizon TTLs rounding up to immortal.
pub fn check_ttl_semantics(t: &dyn ConcurrentMap, cfg: &LifecycleConfig) {
    assert!(t.supports_ttl(), "{}: built with lifecycle", t.name());
    let q = cfg.quantum;
    let ks = keys(8, 0x77D1);
    assert_eq!(
        t.upsert_ttl(ks[0], 1, 3 * q, &UpsertOp::InsertIfUnique),
        UpsertResult::Inserted
    );
    assert_eq!(t.query(ks[0]), Some(1), "live TTL entry hits");
    assert_eq!(t.upsert(ks[1], 2, &UpsertOp::InsertIfUnique), UpsertResult::Inserted);
    cfg.clock.advance(3 * q);
    // Expire-on-read: scalar and bulk agree, and nothing bumps a corpse.
    assert_eq!(t.query(ks[0]), None, "expired entry must read absent");
    let mut out = Vec::new();
    t.query_bulk(&[ks[0], ks[1]], &mut out);
    assert_eq!(out, vec![None, Some(2)], "bulk expire-on-read parity");
    assert_eq!(t.entry_frequency(ks[0]), None);
    if t.is_stable() {
        assert!(!t.fetch_add_in_place(ks[0], 1), "no in-place add on a corpse");
    }
    // Upsert over the corpse reclaims in place: a fresh insert (no merge
    // with the dead value), exactly one physical copy.
    assert_eq!(t.upsert(ks[0], 7, &UpsertOp::AddAssign), UpsertResult::Inserted);
    assert_eq!(t.query(ks[0]), Some(7), "reclaim is a fresh insert, not a merge");
    assert_eq!(t.count_copies(ks[0]), 1, "reclaim reuses the existing slot");
    // upsert_ttl on a live entry refreshes the deadline, keeps frequency.
    assert_eq!(
        t.upsert_ttl(ks[2], 9, 2 * q, &UpsertOp::Overwrite),
        UpsertResult::Inserted
    );
    assert_eq!(t.query(ks[2]), Some(9));
    assert_eq!(t.entry_frequency(ks[2]), Some(1));
    assert_eq!(
        t.upsert_ttl(ks[2], 10, 5 * q, &UpsertOp::Overwrite),
        UpsertResult::Updated
    );
    cfg.clock.advance(3 * q);
    assert_eq!(t.query(ks[2]), Some(10), "refreshed TTL outlives the original");
    assert_eq!(t.entry_frequency(ks[2]), Some(2), "refresh keeps the counter");
    cfg.clock.advance(2 * q);
    assert_eq!(t.query(ks[2]), None);
    // Erase on an expired entry physically reclaims but reports absent.
    assert!(!t.erase(ks[2]), "erase of a corpse reports absent");
    assert_eq!(t.count_copies(ks[2]), 0, "erase reclaims the corpse");
    // Frequency counter: read-without-bump, bump-per-hit, saturation.
    assert_eq!(
        t.upsert_ttl(ks[3], 1, 7 * q, &UpsertOp::InsertIfUnique),
        UpsertResult::Inserted
    );
    assert_eq!(t.entry_frequency(ks[3]), Some(0));
    assert_eq!(t.entry_frequency(ks[3]), Some(0), "frequency read must not bump");
    for _ in 0..12 {
        assert!(t.query(ks[3]).is_some());
    }
    assert_eq!(t.entry_frequency(ks[3]), Some(FREQ_MAX), "counter saturates");
    // Beyond-horizon TTLs round up to immortal (never expire early).
    assert_eq!(
        t.upsert_ttl(ks[4], 4, (TTL_HORIZON_QUANTA + 5) * q, &UpsertOp::InsertIfUnique),
        UpsertResult::Inserted
    );
    cfg.clock.advance(10 * q);
    assert_eq!(t.query(ks[4]), Some(4), "beyond-horizon TTL must not expire early");
    // No resurrection anywhere after all that clock motion.
    assert_eq!(t.query(ks[0]), Some(7), "reclaimed entry is immortal");
    assert_eq!(t.query(ks[1]), Some(2), "immortal neighbor untouched");
    assert_eq!(t.query(ks[2]), None);
}

/// Background-sweep contract: after expiry, a sequence of bounded
/// `sweep_expired` calls reclaims exactly the expired set (oracle = the
/// insert schedule), leaves every live key intact, and a second full
/// pass finds nothing.
pub fn check_sweep_vs_oracle(t: &dyn ConcurrentMap, cfg: &LifecycleConfig) {
    let ks = keys(120, 0x5EEB);
    for (i, &k) in ks.iter().enumerate() {
        let r = if i % 3 == 0 {
            t.upsert_ttl(k, i as u64, 2 * cfg.quantum, &UpsertOp::InsertIfUnique)
        } else {
            t.upsert(k, i as u64, &UpsertOp::InsertIfUnique)
        };
        assert_eq!(r, UpsertResult::Inserted);
    }
    let mortals = ks.len().div_ceil(3);
    assert_eq!(t.len(), ks.len());
    cfg.clock.advance(2 * cfg.quantum);
    // len() stays physical: corpses occupy slots until swept.
    assert_eq!(t.len(), ks.len());
    let full_cover = (2 * t.num_buckets()).div_ceil(8);
    let mut reclaimed = 0;
    for _ in 0..full_cover {
        reclaimed += t.sweep_expired(8);
    }
    assert_eq!(reclaimed, mortals, "{}: sweep ≠ expiry oracle", t.name());
    assert_eq!(t.swept_expired() as usize, mortals);
    assert_eq!(t.len(), ks.len() - mortals, "sweep frees physical slots");
    for (i, &k) in ks.iter().enumerate() {
        if i % 3 == 0 {
            assert_eq!(t.query(k), None);
            assert_eq!(t.count_copies(k), 0, "swept corpse lingers");
        } else {
            assert_eq!(t.query(k), Some(i as u64), "sweep must not touch live keys");
        }
    }
    let mut again = 0;
    for _ in 0..full_cover {
        again += t.sweep_expired(8);
    }
    assert_eq!(again, 0, "second sweep pass must find nothing");
}

/// Bulk-vs-scalar TTL parity: two twins share one clock; TTL upserts are
/// applied identically to both, then `query_bulk`/`erase_bulk` on one
/// must agree op-for-op with scalar `query`/`erase` on the other across
/// interleaved clock advances.
pub fn check_bulk_ttl_parity(
    bulk_t: &dyn ConcurrentMap,
    scalar_t: &dyn ConcurrentMap,
    cfg: &LifecycleConfig,
    seed: u64,
) {
    let mut rng = Xoshiro256pp::new(seed);
    let universe = keys(96, seed ^ 0x77E1);
    let draw = |rng: &mut Xoshiro256pp| universe[rng.next_below(96) as usize];
    for round in 0..60 {
        let len = 1 + rng.next_below(48) as usize;
        match rng.next_below(5) {
            0 | 1 => {
                for _ in 0..len {
                    let k = draw(&mut rng);
                    let v = rng.next_below(1_000);
                    let ttl = (1 + rng.next_below(6)) * cfg.quantum;
                    let a = bulk_t.upsert_ttl(k, v, ttl, &UpsertOp::Overwrite);
                    let b = scalar_t.upsert_ttl(k, v, ttl, &UpsertOp::Overwrite);
                    assert_eq!(a, b, "{}: round {round} upsert_ttl {k:#x}", bulk_t.name());
                }
            }
            2 => {
                let ks: Vec<u64> = (0..len).map(|_| draw(&mut rng)).collect();
                let mut bulk_res = Vec::new();
                bulk_t.query_bulk(&ks, &mut bulk_res);
                for (i, &k) in ks.iter().enumerate() {
                    assert_eq!(
                        bulk_res[i],
                        scalar_t.query(k),
                        "{}: round {round} query #{i} key {k:#x}",
                        bulk_t.name()
                    );
                }
            }
            3 => {
                let ks: Vec<u64> = (0..len).map(|_| draw(&mut rng)).collect();
                let mut bulk_res = Vec::new();
                bulk_t.erase_bulk(&ks, &mut bulk_res);
                for (i, &k) in ks.iter().enumerate() {
                    assert_eq!(
                        bulk_res[i],
                        scalar_t.erase(k),
                        "{}: round {round} erase #{i} key {k:#x}",
                        bulk_t.name()
                    );
                }
            }
            _ => {
                cfg.clock.advance(cfg.quantum);
            }
        }
    }
}

/// The acceptance criterion's line-count proof: the lifecycle twin's
/// query hot path must touch exactly as many cache lines as the plain
/// twin's — colocated codes ride lines the tag probe already pays for,
/// so frequency bumps are free. (Run only on colocated designs; the
/// standalone code array honestly adds its own line.)
pub fn check_query_line_parity(
    plain: &dyn ConcurrentMap,
    life: &dyn ConcurrentMap,
    cfg: &LifecycleConfig,
    seed: u64,
) {
    use crate::gpusim::probes::{self, ProbeScope};
    let ks = keys(200, seed);
    for (i, &k) in ks.iter().enumerate() {
        assert_eq!(
            plain.upsert(k, i as u64, &UpsertOp::InsertIfUnique),
            UpsertResult::Inserted
        );
        assert_eq!(
            life.upsert_ttl(k, i as u64, TTL_HORIZON_QUANTA * cfg.quantum, &UpsertOp::InsertIfUnique),
            UpsertResult::Inserted
        );
    }
    let _measure = probes::measurement_section();
    probes::set_enabled(true);
    let count = |t: &dyn ConcurrentMap| {
        let mut lines = 0usize;
        for &k in &ks {
            let s = ProbeScope::begin();
            assert!(t.query(k).is_some());
            lines += s.finish();
        }
        lines
    };
    let base = count(plain);
    let with_life = count(life);
    assert_eq!(
        with_life, base,
        "{}: frequency bumps must not add probe lines",
        life.name()
    );
}

/// Random op stream checked against `std::collections::HashMap`.
pub fn check_vs_oracle(t: &dyn ConcurrentMap, seed: u64) {
    let mut rng = Xoshiro256pp::new(seed);
    let universe = keys(256, seed ^ 0xABCD);
    let mut oracle: HashMap<u64, u64> = HashMap::new();
    for step in 0..8_192 {
        let k = universe[rng.next_below(universe.len() as u64) as usize];
        match rng.next_below(10) {
            0..=3 => {
                let v = rng.next_u64() >> 1;
                let r = t.upsert(k, v, &UpsertOp::Overwrite);
                let was = oracle.insert(k, v);
                assert_eq!(
                    r,
                    if was.is_some() {
                        UpsertResult::Updated
                    } else {
                        UpsertResult::Inserted
                    },
                    "step {step}"
                );
            }
            4..=5 => {
                let v = rng.next_below(1000);
                let r = t.upsert(k, v, &UpsertOp::AddAssign);
                match oracle.get_mut(&k) {
                    Some(ov) => {
                        *ov = ov.wrapping_add(v);
                        assert_eq!(r, UpsertResult::Updated, "step {step}");
                    }
                    None => {
                        oracle.insert(k, v);
                        assert_eq!(r, UpsertResult::Inserted, "step {step}");
                    }
                }
            }
            6..=7 => {
                assert_eq!(t.erase(k), oracle.remove(&k).is_some(), "step {step}");
            }
            _ => {
                assert_eq!(t.query(k), oracle.get(&k).copied(), "step {step}");
            }
        }
    }
    assert_eq!(t.len(), oracle.len());
    for (k, v) in &oracle {
        assert_eq!(t.query(*k), Some(*v));
        assert_eq!(t.count_copies(*k), 1);
    }
}
