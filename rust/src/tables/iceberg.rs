//! IcebergHT / IcebergHT(M) — front-yard/back-yard hashing (paper §2.2,
//! §5; Pandey et al., SIGMOD'23).
//!
//! The front yard holds ~83% of the slots in large single-hash buckets
//! (32 KV pairs, 4 cache lines). Keys go to their front-yard bucket until
//! it is full, then overflow into the back yard (~17% of slots) which
//! uses power-of-two-choice over small one-line buckets (8 KV pairs).
//!
//! The design is stable (keys never move once placed) and highly
//! concurrent; the metadata variant keeps a 16-bit fingerprint block for
//! both yards, which is what collapses aged negative queries from ~12
//! probes to ~3 (Table 5.1): one tag block in the front yard plus two in
//! the back yard.
//!
//! Key-level serialization uses the lock of the key's *front-yard* bucket
//! regardless of where the key ends up, so upserts/erases of the same key
//! are always mutually exclusive (§4.1) while back-yard slot claims use
//! CAS against inserts hashed from other front-yard buckets.

use std::sync::atomic::{AtomicU64, Ordering};

use super::common::{bucket_count_for, Pairs};
use super::meta::MetaArray;
use super::{ConcurrencyMode, ConcurrentMap, TableConfig, UpsertOp, UpsertResult};
use crate::gpusim::race::RaceEvent;
use crate::gpusim::LockArray;
use crate::hash::{hash1, hash2, hash3, tag16};

/// Fraction of slots assigned to the front yard (paper §5: 83%).
const FRONT_FRACTION: f64 = 0.83;
/// Back-yard bucket size: one cache line.
const BACK_BUCKET: usize = 8;

pub struct IcebergHt {
    front: Pairs,
    back: Pairs,
    fmeta: Option<MetaArray>,
    bmeta: Option<MetaArray>,
    locks: LockArray,
    mode: ConcurrencyMode,
    hook: std::sync::Arc<dyn crate::gpusim::race::RaceHook>,
    live: AtomicU64,
}

impl IcebergHt {
    pub fn new(cfg: TableConfig, with_meta: bool) -> Self {
        let front_slots = ((cfg.slots as f64) * FRONT_FRACTION) as usize;
        let back_slots = cfg.slots - front_slots;
        let nf = bucket_count_for(front_slots.max(cfg.bucket_size), cfg.bucket_size);
        let nb = bucket_count_for(back_slots.max(BACK_BUCKET), BACK_BUCKET);
        let front = Pairs::new(nf, cfg.bucket_size, cfg.tile_size);
        let back = Pairs::new(nb, BACK_BUCKET, cfg.tile_size.min(BACK_BUCKET));
        let fmeta = with_meta.then(|| MetaArray::new(nf, cfg.bucket_size));
        let bmeta = with_meta.then(|| MetaArray::new(nb, BACK_BUCKET));
        Self {
            front,
            back,
            fmeta,
            bmeta,
            locks: LockArray::new(nf),
            mode: cfg.mode,
            hook: cfg.hook,
            live: AtomicU64::new(0),
        }
    }

    #[inline(always)]
    fn front_bucket(&self, key: u64) -> usize {
        (hash1(key) & self.front.mask()) as usize
    }

    #[inline(always)]
    fn back_buckets(&self, key: u64) -> [usize; 2] {
        let mask = self.back.mask();
        [(hash2(key) & mask) as usize, (hash3(key) & mask) as usize]
    }

    /// Scan one bucket of either yard via metadata when present.
    fn find_in(
        &self,
        pairs: &Pairs,
        meta: &Option<MetaArray>,
        b: usize,
        key: u64,
        tag: u16,
        strong: bool,
    ) -> (Option<(usize, u64)>, Option<usize>, usize) {
        if let Some(m) = meta {
            let ms = m.scan(b, tag, strong);
            let found = pairs.scan_slots(b, ms.match_slots(), key, strong);
            (found, ms.reusable(), ms.fill)
        } else {
            let r = pairs.scan_bucket(b, key, strong);
            (r.found, r.reusable(), r.fill)
        }
    }

    fn claim_in(
        &self,
        pairs: &Pairs,
        meta: &Option<MetaArray>,
        b: usize,
        key: u64,
        val: u64,
        tag: u16,
    ) -> bool {
        let strong = self.mode.strong();
        loop {
            let slot = if let Some(m) = meta {
                match m.scan(b, tag, strong).reusable() {
                    Some(s) => s,
                    None => return false,
                }
            } else {
                match pairs.scan_bucket(b, key, strong).reusable() {
                    Some(s) => s,
                    None => return false,
                }
            };
            self.hook.on_event(RaceEvent::BeforeClaim { key, bucket: b });
            if let Some(m) = meta {
                if m.try_claim(b, slot, tag, true) {
                    let ok = pairs.try_claim(b, slot, true);
                    debug_assert!(ok);
                    pairs.publish(b, slot, key, val);
                    return true;
                }
            } else if pairs.try_claim(b, slot, true) {
                pairs.publish(b, slot, key, val);
                return true;
            }
        }
    }

    fn apply_existing(
        &self,
        pairs: &Pairs,
        b: usize,
        slot: usize,
        old_v: u64,
        val: u64,
        op: &UpsertOp,
    ) {
        match op.merge(old_v, val) {
            Some(newv) => {
                if newv != old_v {
                    pairs.value_store(b, slot, newv);
                }
            }
            None => match op {
                UpsertOp::AddAssign => pairs.value_fetch_add(b, slot, val),
                UpsertOp::AddAssignF64 => pairs.value_fetch_add_f64(b, slot, f64::from_bits(val)),
                _ => unreachable!(),
            },
        }
    }

    /// Locate `key` anywhere: front yard first, then both back buckets.
    fn locate(&self, key: u64, strong: bool) -> Option<(&Pairs, usize, usize, u64)> {
        // Hoisted per-op tag (two fmix64 rounds — §Perf).
        let tag = if self.fmeta.is_some() { tag16(key) } else { 0 };
        let fb = self.front_bucket(key);
        let (found, _, _) = self.find_in(&self.front, &self.fmeta, fb, key, tag, strong);
        if let Some((slot, v)) = found {
            return Some((&self.front, fb, slot, v));
        }
        for bb in self.back_buckets(key) {
            let (found, _, _) = self.find_in(&self.back, &self.bmeta, bb, key, tag, strong);
            if let Some((slot, v)) = found {
                return Some((&self.back, bb, slot, v));
            }
        }
        None
    }

    fn meta_for(&self, pairs: &Pairs) -> &Option<MetaArray> {
        if std::ptr::eq(pairs, &self.front) {
            &self.fmeta
        } else {
            &self.bmeta
        }
    }
}

impl ConcurrentMap for IcebergHt {
    fn upsert(&self, key: u64, val: u64, op: &UpsertOp) -> UpsertResult {
        debug_assert!(crate::gpusim::mem::is_user_key(key));
        let fb = self.front_bucket(key);
        if self.mode.locking() {
            self.locks.lock(fb);
        }
        let strong = self.mode.strong();
        let res = 'done: {
            if let Some((pairs, b, slot, old_v)) = self.locate(key, strong) {
                self.apply_existing(pairs, b, slot, old_v, val, op);
                break 'done UpsertResult::Updated;
            }
            let tag = if self.fmeta.is_some() { tag16(key) } else { 0 };
            // Front yard first.
            if self.claim_in(&self.front, &self.fmeta, fb, key, val, tag) {
                self.live.fetch_add(1, Ordering::Relaxed);
                break 'done UpsertResult::Inserted;
            }
            self.hook
                .on_event(RaceEvent::PrimaryFullMovingOn { key, bucket: fb });
            // Back yard: power-of-two-choice between the two candidates.
            let [bb1, bb2] = self.back_buckets(key);
            let (_, _, f1) = self.find_in(&self.back, &self.bmeta, bb1, key, tag, strong);
            let (_, _, f2) = self.find_in(&self.back, &self.bmeta, bb2, key, tag, strong);
            let order = if f1 <= f2 { [bb1, bb2] } else { [bb2, bb1] };
            for bb in order {
                if self.claim_in(&self.back, &self.bmeta, bb, key, val, tag) {
                    self.live.fetch_add(1, Ordering::Relaxed);
                    break 'done UpsertResult::Inserted;
                }
            }
            UpsertResult::Full
        };
        if self.mode.locking() {
            self.locks.unlock(fb);
        }
        res
    }

    fn query(&self, key: u64) -> Option<u64> {
        self.locate(key, self.mode.strong()).map(|(_, _, _, v)| v)
    }

    fn erase(&self, key: u64) -> bool {
        let fb = self.front_bucket(key);
        if self.mode.locking() {
            self.locks.lock(fb);
        }
        let hit = match self.locate(key, self.mode.strong()) {
            Some((pairs, b, slot, _)) => {
                pairs.kill(b, slot);
                if let Some(m) = self.meta_for(pairs) {
                    m.kill(b, slot);
                }
                self.live.fetch_sub(1, Ordering::Relaxed);
                self.hook.on_event(RaceEvent::AfterDelete { key, bucket: b });
                true
            }
            None => false,
        };
        if self.mode.locking() {
            self.locks.unlock(fb);
        }
        hit
    }

    fn num_buckets(&self) -> usize {
        self.front.num_buckets
    }

    fn primary_bucket(&self, key: u64) -> usize {
        self.front_bucket(key)
    }

    fn capacity(&self) -> usize {
        self.front.num_buckets * self.front.bucket_size
            + self.back.num_buckets * self.back.bucket_size
    }

    fn len(&self) -> usize {
        self.live.load(Ordering::Relaxed) as usize
    }

    fn device_bytes(&self) -> usize {
        self.front.device_bytes()
            + self.back.device_bytes()
            + self.fmeta.as_ref().map_or(0, |m| m.device_bytes())
            + self.bmeta.as_ref().map_or(0, |m| m.device_bytes())
            + self.locks.bytes()
    }

    fn name(&self) -> &'static str {
        if self.fmeta.is_some() {
            "IcebergHT(M)"
        } else {
            "IcebergHT"
        }
    }

    fn is_stable(&self) -> bool {
        true
    }

    fn fetch_add_in_place(&self, key: u64, v: u64) -> bool {
        match self.locate(key, self.mode.strong()) {
            Some((pairs, b, slot, _)) => {
                pairs.value_fetch_add(b, slot, v);
                true
            }
            None => false,
        }
    }

    fn fetch_add_f64_in_place(&self, key: u64, v: f64) -> bool {
        match self.locate(key, self.mode.strong()) {
            Some((pairs, b, slot, _)) => {
                pairs.value_fetch_add_f64(b, slot, v);
                true
            }
            None => false,
        }
    }

    fn for_each_entry(&self, f: &mut dyn FnMut(u64, u64)) {
        self.front.for_each_live(|k, v| f(k, v));
        self.back.for_each_live(|k, v| f(k, v));
    }

    fn count_copies(&self, key: u64) -> usize {
        self.front.count_copies(key) + self.back.count_copies(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tables::test_support::*;

    fn plain(slots: usize) -> IcebergHt {
        IcebergHt::new(TableConfig::new(slots).with_geometry(32, 8), false)
    }

    fn meta(slots: usize) -> IcebergHt {
        IcebergHt::new(TableConfig::new(slots).with_geometry(32, 4), true)
    }

    #[test]
    fn basic_crud() {
        check_basic_crud(&plain(2048));
        check_basic_crud(&meta(2048));
    }

    #[test]
    fn fills_to_90_percent() {
        check_fill_to(&plain(8192), 0.90);
        check_fill_to(&meta(8192), 0.90);
    }

    #[test]
    fn upsert_policies() {
        check_upsert_policies(&plain(2048));
        check_upsert_policies(&meta(2048));
    }

    #[test]
    fn aging_churn() {
        check_aging_churn(&plain(4096), 40);
        check_aging_churn(&meta(4096), 40);
    }

    #[test]
    fn concurrent_no_duplicates() {
        check_concurrent_no_duplicates(std::sync::Arc::new(plain(8192)));
        check_concurrent_no_duplicates(std::sync::Arc::new(meta(8192)));
    }

    #[test]
    fn concurrent_mixed() {
        check_concurrent_mixed(std::sync::Arc::new(plain(8192)));
    }

    #[test]
    fn in_place_accumulate() {
        check_fetch_add_in_place(&plain(2048));
        check_fetch_add_in_place(&meta(2048));
    }

    #[test]
    fn oracle_equivalence() {
        check_vs_oracle(&plain(4096), 0x31);
        check_vs_oracle(&meta(4096), 0x32);
    }

    #[test]
    fn front_yard_holds_low_load_keys() {
        let t = plain(8192);
        let ks = keys(64, 0x1CE);
        for &k in &ks {
            t.upsert(k, 1, &UpsertOp::InsertIfUnique);
        }
        for &k in &ks {
            let fb = t.front_bucket(k);
            assert!(
                t.front.scan_bucket(fb, k, true).found.is_some(),
                "low-load key must sit in the front yard"
            );
        }
    }

    #[test]
    fn overflow_goes_to_backyard() {
        // Tiny front yard overfilled past its slot count: overflow is
        // forced into the back yard and keys must remain findable.
        let t = IcebergHt::new(TableConfig::new(256).with_geometry(32, 8), false);
        let front_cap = t.front.num_buckets * t.front.bucket_size;
        let ks = keys(front_cap + 40, 0xBEE);
        let mut inserted = vec![];
        for &k in &ks {
            if t.upsert(k, k ^ 7, &UpsertOp::InsertIfUnique) == UpsertResult::Inserted {
                inserted.push(k);
            }
        }
        assert!(inserted.len() > front_cap, "must exceed front-yard capacity");
        for &k in &inserted {
            assert_eq!(t.query(k), Some(k ^ 7));
        }
        // Some keys must actually be in the back yard.
        let in_back = inserted
            .iter()
            .filter(|&&k| t.back.count_copies(k) == 1)
            .count();
        assert!(in_back > 0, "no key overflowed to the back yard");
    }
}
