//! IcebergHT / IcebergHT(M) — front-yard/back-yard hashing (paper §2.2,
//! §5; Pandey et al., SIGMOD'23).
//!
//! The front yard holds ~83% of the slots in large single-hash buckets
//! (32 KV pairs, 4 cache lines). Keys go to their front-yard bucket until
//! it is full, then overflow into the back yard (~17% of slots) which
//! uses power-of-two-choice over small one-line buckets (8 KV pairs).
//!
//! The design is stable (keys never move once placed) and highly
//! concurrent; the metadata variant keeps a 16-bit fingerprint block for
//! both yards, which is what collapses aged negative queries from ~12
//! probes to ~3 (Table 5.1): one tag block in the front yard plus two in
//! the back yard.
//!
//! Key-level serialization uses the lock of the key's *front-yard* bucket
//! regardless of where the key ends up, so upserts/erases of the same key
//! are always mutually exclusive (§4.1) while back-yard slot claims use
//! CAS against inserts hashed from other front-yard buckets.

use std::sync::atomic::{AtomicU64, Ordering};

use super::common::{bucket_count_for, FreeSlots, Pairs};
use super::meta::{MetaArray, MetaScan};
use super::{ConcurrencyMode, ConcurrentMap, TableConfig, UpsertOp, UpsertResult};
use crate::gpusim::race::RaceEvent;
use crate::gpusim::LockArray;
use crate::hash::{hash1, hash2, hash3, tag16};

/// Fraction of slots assigned to the front yard (paper §5: 83%).
const FRONT_FRACTION: f64 = 0.83;
/// Back-yard bucket size: one cache line.
const BACK_BUCKET: usize = 8;

pub struct IcebergHt {
    front: Pairs,
    back: Pairs,
    fmeta: Option<MetaArray>,
    bmeta: Option<MetaArray>,
    locks: LockArray,
    mode: ConcurrencyMode,
    hook: std::sync::Arc<dyn crate::gpusim::race::RaceHook>,
    live: AtomicU64,
}

impl IcebergHt {
    pub fn new(cfg: TableConfig, with_meta: bool) -> Self {
        let front_slots = ((cfg.slots as f64) * FRONT_FRACTION) as usize;
        let back_slots = cfg.slots - front_slots;
        let nf = bucket_count_for(front_slots.max(cfg.bucket_size), cfg.bucket_size);
        let nb = bucket_count_for(back_slots.max(BACK_BUCKET), BACK_BUCKET);
        let front = Pairs::new(nf, cfg.bucket_size, cfg.tile_size);
        let back = Pairs::new(nb, BACK_BUCKET, cfg.tile_size.min(BACK_BUCKET));
        let fmeta = with_meta.then(|| MetaArray::new(nf, cfg.bucket_size));
        let bmeta = with_meta.then(|| MetaArray::new(nb, BACK_BUCKET));
        Self {
            front,
            back,
            fmeta,
            bmeta,
            locks: LockArray::new(nf),
            mode: cfg.mode,
            hook: cfg.hook,
            live: AtomicU64::new(0),
        }
    }

    #[inline(always)]
    fn front_bucket(&self, key: u64) -> usize {
        (hash1(key) & self.front.mask()) as usize
    }

    #[inline(always)]
    fn back_buckets(&self, key: u64) -> [usize; 2] {
        let mask = self.back.mask();
        [(hash2(key) & mask) as usize, (hash3(key) & mask) as usize]
    }

    /// Scan one bucket of either yard via metadata when present.
    fn find_in(
        &self,
        pairs: &Pairs,
        meta: &Option<MetaArray>,
        b: usize,
        key: u64,
        tag: u16,
        strong: bool,
    ) -> (Option<(usize, u64)>, Option<usize>, usize) {
        if let Some(m) = meta {
            let ms = m.scan(b, tag, strong);
            let found = pairs.scan_slots(b, ms.match_slots(), key, strong);
            (found, ms.reusable(), ms.fill)
        } else {
            let r = pairs.scan_bucket(b, key, strong);
            (r.found, r.reusable(), r.fill)
        }
    }

    fn claim_in(
        &self,
        pairs: &Pairs,
        meta: &Option<MetaArray>,
        b: usize,
        key: u64,
        val: u64,
        tag: u16,
    ) -> bool {
        let strong = self.mode.strong();
        loop {
            let slot = if let Some(m) = meta {
                match m.scan(b, tag, strong).reusable() {
                    Some(s) => s,
                    None => return false,
                }
            } else {
                match pairs.scan_bucket(b, key, strong).reusable() {
                    Some(s) => s,
                    None => return false,
                }
            };
            self.hook.on_event(RaceEvent::BeforeClaim { key, bucket: b });
            if let Some(m) = meta {
                if m.try_claim(b, slot, tag, true) {
                    let ok = pairs.try_claim(b, slot, true);
                    debug_assert!(ok);
                    pairs.publish(b, slot, key, val);
                    return true;
                }
            } else if pairs.try_claim(b, slot, true) {
                pairs.publish(b, slot, key, val);
                return true;
            }
        }
    }

    fn apply_existing(
        &self,
        pairs: &Pairs,
        b: usize,
        slot: usize,
        old_v: u64,
        val: u64,
        op: &UpsertOp,
    ) {
        match op.merge(old_v, val) {
            Some(newv) => {
                if newv != old_v {
                    pairs.value_store(b, slot, newv);
                }
            }
            None => match op {
                UpsertOp::AddAssign => pairs.value_fetch_add(b, slot, val),
                UpsertOp::AddAssignF64 => pairs.value_fetch_add_f64(b, slot, f64::from_bits(val)),
                _ => unreachable!(),
            },
        }
    }

    /// Locate `key` anywhere: front yard first, then both back buckets.
    fn locate(&self, key: u64, strong: bool) -> Option<(&Pairs, usize, usize, u64)> {
        // Hoisted per-op tag (two fmix64 rounds — §Perf).
        let tag = if self.fmeta.is_some() { tag16(key) } else { 0 };
        let fb = self.front_bucket(key);
        let (found, _, _) = self.find_in(&self.front, &self.fmeta, fb, key, tag, strong);
        if let Some((slot, v)) = found {
            return Some((&self.front, fb, slot, v));
        }
        for bb in self.back_buckets(key) {
            let (found, _, _) = self.find_in(&self.back, &self.bmeta, bb, key, tag, strong);
            if let Some((slot, v)) = found {
                return Some((&self.back, bb, slot, v));
            }
        }
        None
    }

    fn meta_for(&self, pairs: &Pairs) -> &Option<MetaArray> {
        if std::ptr::eq(pairs, &self.front) {
            &self.fmeta
        } else {
            &self.bmeta
        }
    }

    /// Scalar upsert body; the caller holds the front-yard bucket lock
    /// (in locking modes). Shared by the scalar API and the bulk
    /// fallback.
    fn upsert_under_lock(&self, key: u64, val: u64, op: &UpsertOp) -> UpsertResult {
        let fb = self.front_bucket(key);
        let strong = self.mode.strong();
        let res = 'done: {
            if let Some((pairs, b, slot, old_v)) = self.locate(key, strong) {
                self.apply_existing(pairs, b, slot, old_v, val, op);
                break 'done UpsertResult::Updated;
            }
            let tag = if self.fmeta.is_some() { tag16(key) } else { 0 };
            // Front yard first.
            if self.claim_in(&self.front, &self.fmeta, fb, key, val, tag) {
                self.live.fetch_add(1, Ordering::Relaxed);
                break 'done UpsertResult::Inserted;
            }
            self.hook
                .on_event(RaceEvent::PrimaryFullMovingOn { key, bucket: fb });
            // Back yard: power-of-two-choice between the two candidates.
            let [bb1, bb2] = self.back_buckets(key);
            let (_, _, f1) = self.find_in(&self.back, &self.bmeta, bb1, key, tag, strong);
            let (_, _, f2) = self.find_in(&self.back, &self.bmeta, bb2, key, tag, strong);
            let order = if f1 <= f2 { [bb1, bb2] } else { [bb2, bb1] };
            for bb in order {
                if self.claim_in(&self.back, &self.bmeta, bb, key, val, tag) {
                    self.live.fetch_add(1, Ordering::Relaxed);
                    break 'done UpsertResult::Inserted;
                }
            }
            UpsertResult::Full
        };
        res
    }

    /// Scalar erase body; caller holds the front-yard bucket lock.
    fn erase_under_lock(&self, key: u64) -> bool {
        match self.locate(key, self.mode.strong()) {
            Some((pairs, b, slot, _)) => {
                self.kill_in(pairs, b, slot, key);
                true
            }
            None => false,
        }
    }

    /// Tombstone a located pair in either yard and account the deletion.
    fn kill_in(&self, pairs: &Pairs, b: usize, slot: usize, key: u64) {
        pairs.kill(b, slot);
        if let Some(m) = self.meta_for(pairs) {
            m.kill(b, slot);
        }
        self.live.fetch_sub(1, Ordering::Relaxed);
        self.hook.on_event(RaceEvent::AfterDelete { key, bucket: b });
    }

    /// Find `key` in the back yard only (both candidate buckets).
    fn locate_back(&self, key: u64, tag: u16, strong: bool) -> Option<(usize, usize, u64)> {
        for bb in self.back_buckets(key) {
            let (found, _, _) = self.find_in(&self.back, &self.bmeta, bb, key, tag, strong);
            if let Some((slot, v)) = found {
                return Some((bb, slot, v));
            }
        }
        None
    }

    /// Claim + publish a front-yard slot from a group's shared free-slot
    /// list (shared protocol in [`super::common::claim_from_free`]);
    /// `None` when the scan-time list is exhausted (the caller falls
    /// back to the scalar walk, which retries the front yard and then
    /// overflows to the back yard).
    fn claim_front_from(
        &self,
        fb: usize,
        free: &mut FreeSlots,
        key: u64,
        val: u64,
    ) -> Option<usize> {
        let tag = if self.fmeta.is_some() { tag16(key) } else { 0 };
        super::common::claim_from_free(
            &self.front,
            self.fmeta.as_ref(),
            fb,
            free,
            key,
            val,
            tag,
            self.hook.as_ref(),
        )
    }
}

impl ConcurrentMap for IcebergHt {
    fn upsert(&self, key: u64, val: u64, op: &UpsertOp) -> UpsertResult {
        debug_assert!(crate::gpusim::mem::is_user_key(key));
        let fb = self.front_bucket(key);
        if self.mode.locking() {
            self.locks.lock(fb);
        }
        let res = self.upsert_under_lock(key, val, op);
        if self.mode.locking() {
            self.locks.unlock(fb);
        }
        res
    }

    fn query(&self, key: u64) -> Option<u64> {
        self.locate(key, self.mode.strong()).map(|(_, _, _, v)| v)
    }

    fn erase(&self, key: u64) -> bool {
        let fb = self.front_bucket(key);
        if self.mode.locking() {
            self.locks.lock(fb);
        }
        let hit = self.erase_under_lock(key);
        if self.mode.locking() {
            self.locks.unlock(fb);
        }
        hit
    }

    fn upsert_bulk(&self, pairs_in: &[(u64, u64)], op: &UpsertOp, out: &mut Vec<UpsertResult>) {
        let base = out.len();
        out.resize(base + pairs_in.len(), UpsertResult::Full);
        let mut slots = super::SlotWriter::new(&mut out[base..]);
        let buckets: Vec<usize> =
            pairs_in.iter().map(|&(k, _)| self.front_bucket(k)).collect();
        let locking = self.mode.locking();
        let strong = self.mode.strong();
        let mut tags: Vec<u16> = Vec::new();
        let mut per_tag: Vec<MetaScan> = Vec::new();
        let mut found: Vec<Option<(usize, u64)>> = Vec::new();
        let mut group_keys: Vec<u64> = Vec::new();
        super::for_each_bucket_group(&buckets, |fb, group| {
            if locking {
                self.locks.lock(fb);
            }
            if group.len() == 1 {
                let (k, v) = pairs_in[group[0] as usize];
                debug_assert!(crate::gpusim::mem::is_user_key(k));
                slots.set(group[0] as usize, self.upsert_under_lock(k, v, op));
            } else {
                // One shared scan of the group's common front-yard bucket
                // (one tag-block probe for the metadata variant).
                let mut free = if let Some(meta) = &self.fmeta {
                    tags.clear();
                    tags.extend(group.iter().map(|&i| tag16(pairs_in[i as usize].0)));
                    meta.scan_group(fb, &tags, strong, &mut per_tag).0
                } else {
                    group_keys.clear();
                    group_keys.extend(group.iter().map(|&i| pairs_in[i as usize].0));
                    self.front
                        .scan_bucket_group(fb, &group_keys, strong, &mut found)
                        .0
                };
                let mut local: Vec<(u64, usize)> = Vec::new();
                let mut fallback_keys: Vec<u64> = Vec::new();
                for (j, &i) in group.iter().enumerate() {
                    let (k, v) = pairs_in[i as usize];
                    debug_assert!(crate::gpusim::mem::is_user_key(k));
                    if let Some(&(_, slot)) = local.iter().find(|&&(lk, _)| lk == k) {
                        let (_, old) = self.front.pair_at(fb, slot, strong);
                        self.apply_existing(&self.front, fb, slot, old, v, op);
                        slots.set(i as usize, UpsertResult::Updated);
                        continue;
                    }
                    if fallback_keys.contains(&k) {
                        slots.set(i as usize, self.upsert_under_lock(k, v, op));
                        continue;
                    }
                    let front_hit = if self.fmeta.is_some() {
                        self.front.scan_slots(fb, per_tag[j].match_slots(), k, strong)
                    } else {
                        found[j]
                    };
                    if let Some((slot, _)) = front_hit {
                        // Fresh value read: the shared scan may predate
                        // merges applied earlier in this group.
                        let (_, old) = self.front.pair_at(fb, slot, strong);
                        self.apply_existing(&self.front, fb, slot, old, v, op);
                        slots.set(i as usize, UpsertResult::Updated);
                        continue;
                    }
                    // Not in the front yard — the key may still live in
                    // the back yard (no early exit exists for iceberg).
                    let tag = if self.fmeta.is_some() { tag16(k) } else { 0 };
                    if let Some((bb, slot, old)) = self.locate_back(k, tag, strong) {
                        self.apply_existing(&self.back, bb, slot, old, v, op);
                        slots.set(i as usize, UpsertResult::Updated);
                        continue;
                    }
                    // Absent: front yard first, from the shared free
                    // list; overflow to the back yard via the fallback.
                    if let Some(slot) = self.claim_front_from(fb, &mut free, k, v) {
                        self.live.fetch_add(1, Ordering::Relaxed);
                        local.push((k, slot));
                        slots.set(i as usize, UpsertResult::Inserted);
                        continue;
                    }
                    slots.set(i as usize, self.upsert_under_lock(k, v, op));
                    fallback_keys.push(k);
                }
            }
            if locking {
                self.locks.unlock(fb);
            }
        });
        slots.finish("IcebergHT::upsert_bulk");
    }

    fn query_bulk(&self, keys_in: &[u64], out: &mut Vec<Option<u64>>) {
        let base = out.len();
        out.resize(base + keys_in.len(), None);
        let mut slots = super::SlotWriter::new(&mut out[base..]);
        let buckets: Vec<usize> = keys_in.iter().map(|&k| self.front_bucket(k)).collect();
        let strong = self.mode.strong();
        let mut tags: Vec<u16> = Vec::new();
        let mut per_tag: Vec<MetaScan> = Vec::new();
        let mut found: Vec<Option<(usize, u64)>> = Vec::new();
        let mut group_keys: Vec<u64> = Vec::new();
        super::for_each_bucket_group(&buckets, |fb, group| {
            if group.len() == 1 {
                let i = group[0] as usize;
                slots.set(i, self.query(keys_in[i]));
                return;
            }
            if let Some(meta) = &self.fmeta {
                tags.clear();
                tags.extend(group.iter().map(|&i| tag16(keys_in[i as usize])));
                meta.scan_group(fb, &tags, strong, &mut per_tag);
            } else {
                group_keys.clear();
                group_keys.extend(group.iter().map(|&i| keys_in[i as usize]));
                self.front.scan_bucket_group(fb, &group_keys, strong, &mut found);
            }
            for (j, &i) in group.iter().enumerate() {
                let k = keys_in[i as usize];
                let front_hit = if self.fmeta.is_some() {
                    self.front
                        .scan_slots(fb, per_tag[j].match_slots(), k, strong)
                        .map(|(_, v)| v)
                } else {
                    found[j].map(|(_, v)| v)
                };
                slots.set(
                    i as usize,
                    front_hit.or_else(|| {
                        let tag = if self.fmeta.is_some() { tag16(k) } else { 0 };
                        self.locate_back(k, tag, strong).map(|(_, _, v)| v)
                    }),
                );
            }
        });
        slots.finish("IcebergHT::query_bulk");
    }

    fn erase_bulk(&self, keys_in: &[u64], out: &mut Vec<bool>) {
        let base = out.len();
        out.resize(base + keys_in.len(), false);
        let mut slots = super::SlotWriter::new(&mut out[base..]);
        let buckets: Vec<usize> = keys_in.iter().map(|&k| self.front_bucket(k)).collect();
        let locking = self.mode.locking();
        let strong = self.mode.strong();
        let mut tags: Vec<u16> = Vec::new();
        let mut per_tag: Vec<MetaScan> = Vec::new();
        let mut found: Vec<Option<(usize, u64)>> = Vec::new();
        let mut group_keys: Vec<u64> = Vec::new();
        super::for_each_bucket_group(&buckets, |fb, group| {
            if locking {
                self.locks.lock(fb);
            }
            if group.len() == 1 {
                let i = group[0] as usize;
                slots.set(i, self.erase_under_lock(keys_in[i]));
            } else {
                if self.fmeta.is_some() {
                    tags.clear();
                    tags.extend(group.iter().map(|&i| tag16(keys_in[i as usize])));
                    self.fmeta
                        .as_ref()
                        .unwrap()
                        .scan_group(fb, &tags, strong, &mut per_tag);
                } else {
                    group_keys.clear();
                    group_keys.extend(group.iter().map(|&i| keys_in[i as usize]));
                    self.front.scan_bucket_group(fb, &group_keys, strong, &mut found);
                }
                let mut processed: Vec<u64> = Vec::new();
                for (j, &i) in group.iter().enumerate() {
                    let k = keys_in[i as usize];
                    if processed.contains(&k) {
                        slots.set(i as usize, self.erase_under_lock(k));
                        continue;
                    }
                    processed.push(k);
                    let front_hit = if self.fmeta.is_some() {
                        self.front.scan_slots(fb, per_tag[j].match_slots(), k, strong)
                    } else {
                        found[j]
                    };
                    let hit = if let Some((slot, _)) = front_hit {
                        self.kill_in(&self.front, fb, slot, k);
                        true
                    } else {
                        let tag = if self.fmeta.is_some() { tag16(k) } else { 0 };
                        match self.locate_back(k, tag, strong) {
                            Some((bb, slot, _)) => {
                                self.kill_in(&self.back, bb, slot, k);
                                true
                            }
                            None => false,
                        }
                    };
                    slots.set(i as usize, hit);
                }
            }
            if locking {
                self.locks.unlock(fb);
            }
        });
        slots.finish("IcebergHT::erase_bulk");
    }

    fn num_buckets(&self) -> usize {
        self.front.num_buckets
    }

    fn primary_bucket(&self, key: u64) -> usize {
        self.front_bucket(key)
    }

    fn capacity(&self) -> usize {
        self.front.num_buckets * self.front.bucket_size
            + self.back.num_buckets * self.back.bucket_size
    }

    fn len(&self) -> usize {
        self.live.load(Ordering::Relaxed) as usize
    }

    fn device_bytes(&self) -> usize {
        self.front.device_bytes()
            + self.back.device_bytes()
            + self.fmeta.as_ref().map_or(0, |m| m.device_bytes())
            + self.bmeta.as_ref().map_or(0, |m| m.device_bytes())
            + self.locks.bytes()
    }

    fn name(&self) -> &'static str {
        if self.fmeta.is_some() {
            "IcebergHT(M)"
        } else {
            "IcebergHT"
        }
    }

    fn is_stable(&self) -> bool {
        true
    }

    fn fetch_add_in_place(&self, key: u64, v: u64) -> bool {
        match self.locate(key, self.mode.strong()) {
            Some((pairs, b, slot, _)) => {
                pairs.value_fetch_add(b, slot, v);
                true
            }
            None => false,
        }
    }

    fn fetch_add_f64_in_place(&self, key: u64, v: f64) -> bool {
        match self.locate(key, self.mode.strong()) {
            Some((pairs, b, slot, _)) => {
                pairs.value_fetch_add_f64(b, slot, v);
                true
            }
            None => false,
        }
    }

    fn for_each_entry(&self, f: &mut dyn FnMut(u64, u64)) {
        self.front.for_each_live(|k, v| f(k, v));
        self.back.for_each_live(|k, v| f(k, v));
    }

    fn count_copies(&self, key: u64) -> usize {
        self.front.count_copies(key) + self.back.count_copies(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tables::test_support::*;

    fn plain(slots: usize) -> IcebergHt {
        IcebergHt::new(TableConfig::new(slots).with_geometry(32, 8), false)
    }

    fn meta(slots: usize) -> IcebergHt {
        IcebergHt::new(TableConfig::new(slots).with_geometry(32, 4), true)
    }

    #[test]
    fn basic_crud() {
        check_basic_crud(&plain(2048));
        check_basic_crud(&meta(2048));
    }

    #[test]
    fn fills_to_90_percent() {
        check_fill_to(&plain(8192), 0.90);
        check_fill_to(&meta(8192), 0.90);
    }

    #[test]
    fn upsert_policies() {
        check_upsert_policies(&plain(2048));
        check_upsert_policies(&meta(2048));
    }

    #[test]
    fn aging_churn() {
        check_aging_churn(&plain(4096), 40);
        check_aging_churn(&meta(4096), 40);
    }

    #[test]
    fn concurrent_no_duplicates() {
        check_concurrent_no_duplicates(std::sync::Arc::new(plain(8192)));
        check_concurrent_no_duplicates(std::sync::Arc::new(meta(8192)));
    }

    #[test]
    fn concurrent_mixed() {
        check_concurrent_mixed(std::sync::Arc::new(plain(8192)));
    }

    #[test]
    fn in_place_accumulate() {
        check_fetch_add_in_place(&plain(2048));
        check_fetch_add_in_place(&meta(2048));
    }

    #[test]
    fn oracle_equivalence() {
        check_vs_oracle(&plain(4096), 0x31);
        check_vs_oracle(&meta(4096), 0x32);
    }

    #[test]
    fn front_yard_holds_low_load_keys() {
        let t = plain(8192);
        let ks = keys(64, 0x1CE);
        for &k in &ks {
            t.upsert(k, 1, &UpsertOp::InsertIfUnique);
        }
        for &k in &ks {
            let fb = t.front_bucket(k);
            assert!(
                t.front.scan_bucket(fb, k, true).found.is_some(),
                "low-load key must sit in the front yard"
            );
        }
    }

    #[test]
    fn bulk_matches_scalar_twin() {
        check_bulk_parity(&plain(2048), &plain(2048), 0x33);
        check_bulk_parity(&meta(2048), &meta(2048), 0x34);
    }

    #[test]
    fn bulk_parity_with_backyard_overflow() {
        // Tiny front yards overflow into the back yard; the grouped path
        // must keep finding and erasing back-yard residents.
        check_bulk_parity(&plain(256), &plain(256), 0x35);
        check_bulk_parity(&meta(256), &meta(256), 0x36);
    }

    #[test]
    fn bulk_concurrent_no_duplicates() {
        check_bulk_concurrent_no_duplicates(std::sync::Arc::new(plain(8192)));
        check_bulk_concurrent_no_duplicates(std::sync::Arc::new(meta(8192)));
    }

    #[test]
    fn overflow_goes_to_backyard() {
        // Tiny front yard overfilled past its slot count: overflow is
        // forced into the back yard and keys must remain findable.
        let t = IcebergHt::new(TableConfig::new(256).with_geometry(32, 8), false);
        let front_cap = t.front.num_buckets * t.front.bucket_size;
        let ks = keys(front_cap + 40, 0xBEE);
        let mut inserted = vec![];
        for &k in &ks {
            if t.upsert(k, k ^ 7, &UpsertOp::InsertIfUnique) == UpsertResult::Inserted {
                inserted.push(k);
            }
        }
        assert!(inserted.len() > front_cap, "must exceed front-yard capacity");
        for &k in &inserted {
            assert_eq!(t.query(k), Some(k ^ 7));
        }
        // Some keys must actually be in the back yard.
        let in_back = inserted
            .iter()
            .filter(|&&k| t.back.count_copies(k) == 1)
            .count();
        assert!(in_back > 0, "no key overflowed to the back yard");
    }
}
