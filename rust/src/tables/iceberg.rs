//! IcebergHT / IcebergHT(M) — front-yard/back-yard hashing (paper §2.2,
//! §5; Pandey et al., SIGMOD'23).
//!
//! The front yard holds ~83% of the slots in large single-hash buckets
//! (32 KV pairs, 4 cache lines). Keys go to their front-yard bucket until
//! it is full, then overflow into the back yard (~17% of slots) which
//! uses power-of-two-choice over small one-line buckets (8 KV pairs).
//!
//! The design is stable (keys never move once placed) and highly
//! concurrent; the metadata variant keeps a 16-bit fingerprint block for
//! both yards, which is what collapses aged negative queries from ~12
//! probes to ~3 (Table 5.1): one tag block in the front yard plus two in
//! the back yard.
//!
//! Key-level serialization uses the lock of the key's *front-yard* bucket
//! regardless of where the key ends up, so upserts/erases of the same key
//! are always mutually exclusive (§4.1) while back-yard slot claims use
//! CAS against inserts hashed from other front-yard buckets.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use super::common::{bucket_count_for, FreeSlots, Pairs};
use super::lifecycle::LifecycleSlots;
use super::meta::{MetaArray, MetaScan};
use super::{ConcurrencyMode, ConcurrentMap, TableConfig, UpsertOp, UpsertResult};
use crate::gpusim::race::RaceEvent;
use crate::gpusim::LockArray;
use crate::hash::{hash1, hash2, hash3, tag16};

/// Fraction of slots assigned to the front yard (paper §5: 83%).
const FRONT_FRACTION: f64 = 0.83;
/// Back-yard bucket size: one cache line.
const BACK_BUCKET: usize = 8;

pub struct IcebergHt {
    front: Pairs,
    back: Pairs,
    fmeta: Option<MetaArray>,
    bmeta: Option<MetaArray>,
    locks: LockArray,
    mode: ConcurrencyMode,
    hook: std::sync::Arc<dyn crate::gpusim::race::RaceHook>,
    live: AtomicU64,
    /// TTL + frequency codes spanning BOTH yards: front slots first
    /// (flat `fb * front.bucket_size + slot`), back slots after the
    /// front region. Colocated in the two padded MetaArray regions for
    /// the (M) variant, standalone for the plain variant.
    life: Option<LifecycleSlots>,
    /// Sweep cursor over the combined front+back bucket ring.
    sweep_cursor: AtomicUsize,
    swept: AtomicU64,
}

impl IcebergHt {
    pub fn new(cfg: TableConfig, with_meta: bool) -> Self {
        let front_slots = ((cfg.slots as f64) * FRONT_FRACTION) as usize;
        let back_slots = cfg.slots - front_slots;
        let nf = bucket_count_for(front_slots.max(cfg.bucket_size), cfg.bucket_size);
        let nb = bucket_count_for(back_slots.max(BACK_BUCKET), BACK_BUCKET);
        let front = Pairs::new(nf, cfg.bucket_size, cfg.tile_size);
        let back = Pairs::new(nb, BACK_BUCKET, cfg.tile_size.min(BACK_BUCKET));
        let with_life = cfg.lifecycle.is_some();
        let fmeta = with_meta.then(|| {
            if with_life {
                MetaArray::with_lifecycle_region(nf, cfg.bucket_size)
            } else {
                MetaArray::new(nf, cfg.bucket_size)
            }
        });
        let bmeta = with_meta.then(|| {
            if with_life {
                MetaArray::with_lifecycle_region(nb, BACK_BUCKET)
            } else {
                MetaArray::new(nb, BACK_BUCKET)
            }
        });
        let total_slots = nf * cfg.bucket_size + nb * BACK_BUCKET;
        let life = cfg.lifecycle.clone().map(|lc| {
            if with_meta {
                LifecycleSlots::colocated(lc, total_slots)
            } else {
                LifecycleSlots::standalone(lc, total_slots)
            }
        });
        Self {
            front,
            back,
            fmeta,
            bmeta,
            locks: LockArray::new(nf),
            mode: cfg.mode,
            hook: cfg.hook,
            live: AtomicU64::new(0),
            life,
            sweep_cursor: AtomicUsize::new(0),
            swept: AtomicU64::new(0),
        }
    }

    /// Flat lifecycle index of a slot in either yard (front region
    /// first, back region after it).
    #[inline(always)]
    fn lifeslot_in(&self, pairs: &Pairs, b: usize, slot: usize) -> usize {
        let base = if std::ptr::eq(pairs, &self.front) {
            0
        } else {
            self.front.num_buckets * self.front.bucket_size
        };
        base + b * pairs.bucket_size + slot
    }

    /// Expire-on-read check for a located pair in either yard.
    #[inline]
    fn is_expired_in(&self, pairs: &Pairs, b: usize, slot: usize) -> bool {
        match &self.life {
            Some(l) => {
                if let Some(m) = self.meta_for(pairs) {
                    m.touch_lifecycle(b, slot);
                }
                l.is_expired_at(self.lifeslot_in(pairs, b, slot))
            }
            None => false,
        }
    }

    /// Query-hit bookkeeping: bump frequency; `false` = expired (miss).
    #[inline]
    fn hit_live_in(&self, pairs: &Pairs, b: usize, slot: usize) -> bool {
        match &self.life {
            Some(l) => {
                if let Some(m) = self.meta_for(pairs) {
                    m.touch_lifecycle(b, slot);
                }
                l.on_hit(self.lifeslot_in(pairs, b, slot))
            }
            None => true,
        }
    }

    /// Stamp a just-published slot (benign post-publish race, as in
    /// `DoubleHt::stamp_fresh`).
    #[inline]
    fn stamp_fresh_in(&self, pairs: &Pairs, b: usize, slot: usize, ttl: Option<u64>) {
        if let Some(l) = &self.life {
            if let Some(m) = self.meta_for(pairs) {
                m.touch_lifecycle(b, slot);
            }
            l.fresh(self.lifeslot_in(pairs, b, slot), ttl);
        }
    }

    /// Reclaim an expired pair in place as a fresh insert of `val`.
    #[inline]
    fn reclaim_if_expired_in(
        &self,
        pairs: &Pairs,
        b: usize,
        slot: usize,
        val: u64,
        ttl: Option<u64>,
    ) -> bool {
        if !self.is_expired_in(pairs, b, slot) {
            return false;
        }
        pairs.value_store(b, slot, val);
        self.stamp_fresh_in(pairs, b, slot, ttl);
        true
    }

    #[inline(always)]
    fn front_bucket(&self, key: u64) -> usize {
        (hash1(key) & self.front.mask()) as usize
    }

    #[inline(always)]
    fn back_buckets(&self, key: u64) -> [usize; 2] {
        let mask = self.back.mask();
        [(hash2(key) & mask) as usize, (hash3(key) & mask) as usize]
    }

    /// Scan one bucket of either yard via metadata when present.
    fn find_in(
        &self,
        pairs: &Pairs,
        meta: &Option<MetaArray>,
        b: usize,
        key: u64,
        tag: u16,
        strong: bool,
    ) -> (Option<(usize, u64)>, Option<usize>, usize) {
        if let Some(m) = meta {
            let ms = m.scan(b, tag, strong);
            let found = pairs.scan_slots(b, ms.match_slots(), key, strong);
            (found, ms.reusable(), ms.fill)
        } else {
            let r = pairs.scan_bucket(b, key, strong);
            (r.found, r.reusable(), r.fill)
        }
    }

    /// Claim + publish a free slot in a bucket of either yard; returns
    /// the claimed slot so the caller can stamp lifecycle metadata.
    fn claim_in(
        &self,
        pairs: &Pairs,
        meta: &Option<MetaArray>,
        b: usize,
        key: u64,
        val: u64,
        tag: u16,
    ) -> Option<usize> {
        let strong = self.mode.strong();
        loop {
            let slot = if let Some(m) = meta {
                match m.scan(b, tag, strong).reusable() {
                    Some(s) => s,
                    None => return None,
                }
            } else {
                match pairs.scan_bucket(b, key, strong).reusable() {
                    Some(s) => s,
                    None => return None,
                }
            };
            self.hook.on_event(RaceEvent::BeforeClaim { key, bucket: b });
            if let Some(m) = meta {
                if m.try_claim(b, slot, tag, true) {
                    let ok = pairs.try_claim(b, slot, true);
                    debug_assert!(ok);
                    pairs.publish(b, slot, key, val);
                    return Some(slot);
                }
            } else if pairs.try_claim(b, slot, true) {
                pairs.publish(b, slot, key, val);
                return Some(slot);
            }
        }
    }

    fn apply_existing(
        &self,
        pairs: &Pairs,
        b: usize,
        slot: usize,
        old_v: u64,
        val: u64,
        op: &UpsertOp,
    ) {
        match op.merge(old_v, val) {
            Some(newv) => {
                if newv != old_v {
                    pairs.value_store(b, slot, newv);
                }
            }
            None => match op {
                UpsertOp::AddAssign => pairs.value_fetch_add(b, slot, val),
                UpsertOp::AddAssignF64 => pairs.value_fetch_add_f64(b, slot, f64::from_bits(val)),
                _ => unreachable!(),
            },
        }
    }

    /// Locate `key` anywhere: front yard first, then both back buckets.
    fn locate(&self, key: u64, strong: bool) -> Option<(&Pairs, usize, usize, u64)> {
        // Hoisted per-op tag (two fmix64 rounds — §Perf).
        let tag = if self.fmeta.is_some() { tag16(key) } else { 0 };
        let fb = self.front_bucket(key);
        let (found, _, _) = self.find_in(&self.front, &self.fmeta, fb, key, tag, strong);
        if let Some((slot, v)) = found {
            return Some((&self.front, fb, slot, v));
        }
        for bb in self.back_buckets(key) {
            let (found, _, _) = self.find_in(&self.back, &self.bmeta, bb, key, tag, strong);
            if let Some((slot, v)) = found {
                return Some((&self.back, bb, slot, v));
            }
        }
        None
    }

    fn meta_for(&self, pairs: &Pairs) -> &Option<MetaArray> {
        if std::ptr::eq(pairs, &self.front) {
            &self.fmeta
        } else {
            &self.bmeta
        }
    }

    /// Scalar upsert body; the caller holds the front-yard bucket lock
    /// (in locking modes). Shared by the scalar API and the bulk
    /// fallback.
    fn upsert_under_lock(&self, key: u64, val: u64, op: &UpsertOp, ttl: Option<u64>) -> UpsertResult {
        let fb = self.front_bucket(key);
        let strong = self.mode.strong();
        let res = 'done: {
            if let Some((pairs, b, slot, old_v)) = self.locate(key, strong) {
                if self.reclaim_if_expired_in(pairs, b, slot, val, ttl) {
                    break 'done UpsertResult::Inserted;
                }
                self.apply_existing(pairs, b, slot, old_v, val, op);
                if ttl.is_some() {
                    if let Some(l) = &self.life {
                        l.refresh(self.lifeslot_in(pairs, b, slot), ttl);
                    }
                }
                break 'done UpsertResult::Updated;
            }
            let tag = if self.fmeta.is_some() { tag16(key) } else { 0 };
            // Front yard first.
            if let Some(slot) = self.claim_in(&self.front, &self.fmeta, fb, key, val, tag) {
                self.stamp_fresh_in(&self.front, fb, slot, ttl);
                self.live.fetch_add(1, Ordering::Relaxed);
                break 'done UpsertResult::Inserted;
            }
            self.hook
                .on_event(RaceEvent::PrimaryFullMovingOn { key, bucket: fb });
            // Back yard: power-of-two-choice between the two candidates.
            let [bb1, bb2] = self.back_buckets(key);
            let (_, _, f1) = self.find_in(&self.back, &self.bmeta, bb1, key, tag, strong);
            let (_, _, f2) = self.find_in(&self.back, &self.bmeta, bb2, key, tag, strong);
            let order = if f1 <= f2 { [bb1, bb2] } else { [bb2, bb1] };
            for bb in order {
                if let Some(slot) = self.claim_in(&self.back, &self.bmeta, bb, key, val, tag) {
                    self.stamp_fresh_in(&self.back, bb, slot, ttl);
                    self.live.fetch_add(1, Ordering::Relaxed);
                    break 'done UpsertResult::Inserted;
                }
            }
            UpsertResult::Full
        };
        res
    }

    /// Scalar erase body; caller holds the front-yard bucket lock.
    /// Returns whether a LIVE pair was erased (an expired corpse is
    /// still tombstoned, but reports `false`).
    fn erase_under_lock(&self, key: u64) -> bool {
        match self.locate(key, self.mode.strong()) {
            Some((pairs, b, slot, _)) => {
                let was_live = !self.is_expired_in(pairs, b, slot);
                self.kill_in(pairs, b, slot, key);
                was_live
            }
            None => false,
        }
    }

    /// Tombstone a located pair in either yard and account the deletion.
    fn kill_in(&self, pairs: &Pairs, b: usize, slot: usize, key: u64) {
        pairs.kill(b, slot);
        if let Some(m) = self.meta_for(pairs) {
            m.kill(b, slot);
        }
        if let Some(l) = &self.life {
            l.clear(self.lifeslot_in(pairs, b, slot));
        }
        self.live.fetch_sub(1, Ordering::Relaxed);
        self.hook.on_event(RaceEvent::AfterDelete { key, bucket: b });
    }

    /// Sweep reclaim: tombstone `key` iff it is still present AND still
    /// expired under the front-yard lock (guards against a concurrent
    /// writer having reclaimed the slot between scan and kill).
    fn erase_expired(&self, key: u64) -> bool {
        let fb = self.front_bucket(key);
        if self.mode.locking() {
            self.locks.lock(fb);
        }
        let mut killed = false;
        if let Some((pairs, b, slot, _)) = self.locate(key, self.mode.strong()) {
            if self.is_expired_in(pairs, b, slot) {
                self.kill_in(pairs, b, slot, key);
                killed = true;
            }
        }
        if self.mode.locking() {
            self.locks.unlock(fb);
        }
        killed
    }

    /// Find `key` in the back yard only (both candidate buckets).
    fn locate_back(&self, key: u64, tag: u16, strong: bool) -> Option<(usize, usize, u64)> {
        for bb in self.back_buckets(key) {
            let (found, _, _) = self.find_in(&self.back, &self.bmeta, bb, key, tag, strong);
            if let Some((slot, v)) = found {
                return Some((bb, slot, v));
            }
        }
        None
    }

    /// Claim + publish a front-yard slot from a group's shared free-slot
    /// list (shared protocol in [`super::common::claim_from_free`]);
    /// `None` when the scan-time list is exhausted (the caller falls
    /// back to the scalar walk, which retries the front yard and then
    /// overflows to the back yard).
    fn claim_front_from(
        &self,
        fb: usize,
        free: &mut FreeSlots,
        key: u64,
        val: u64,
    ) -> Option<usize> {
        let tag = if self.fmeta.is_some() { tag16(key) } else { 0 };
        super::common::claim_from_free(
            &self.front,
            self.fmeta.as_ref(),
            fb,
            free,
            key,
            val,
            tag,
            self.hook.as_ref(),
        )
    }
}

impl ConcurrentMap for IcebergHt {
    fn upsert(&self, key: u64, val: u64, op: &UpsertOp) -> UpsertResult {
        debug_assert!(crate::gpusim::mem::is_user_key(key));
        let fb = self.front_bucket(key);
        if self.mode.locking() {
            self.locks.lock(fb);
        }
        let res = self.upsert_under_lock(key, val, op, None);
        if self.mode.locking() {
            self.locks.unlock(fb);
        }
        res
    }

    fn upsert_ttl(&self, key: u64, val: u64, ttl_ticks: u64, op: &UpsertOp) -> UpsertResult {
        if self.life.is_none() {
            return self.upsert(key, val, op);
        }
        debug_assert!(crate::gpusim::mem::is_user_key(key));
        let fb = self.front_bucket(key);
        if self.mode.locking() {
            self.locks.lock(fb);
        }
        let res = self.upsert_under_lock(key, val, op, Some(ttl_ticks));
        if self.mode.locking() {
            self.locks.unlock(fb);
        }
        res
    }

    fn query(&self, key: u64) -> Option<u64> {
        self.locate(key, self.mode.strong())
            .and_then(|(pairs, b, slot, v)| self.hit_live_in(pairs, b, slot).then_some(v))
    }

    fn erase(&self, key: u64) -> bool {
        let fb = self.front_bucket(key);
        if self.mode.locking() {
            self.locks.lock(fb);
        }
        let hit = self.erase_under_lock(key);
        if self.mode.locking() {
            self.locks.unlock(fb);
        }
        hit
    }

    fn upsert_bulk(&self, pairs_in: &[(u64, u64)], op: &UpsertOp, out: &mut Vec<UpsertResult>) {
        let base = out.len();
        out.resize(base + pairs_in.len(), UpsertResult::Full);
        let mut slots = super::SlotWriter::new(&mut out[base..]);
        let buckets: Vec<usize> =
            pairs_in.iter().map(|&(k, _)| self.front_bucket(k)).collect();
        let locking = self.mode.locking();
        let strong = self.mode.strong();
        let mut tags: Vec<u16> = Vec::new();
        let mut per_tag: Vec<MetaScan> = Vec::new();
        let mut found: Vec<Option<(usize, u64)>> = Vec::new();
        let mut group_keys: Vec<u64> = Vec::new();
        super::for_each_bucket_group(&buckets, |fb, group| {
            if locking {
                self.locks.lock(fb);
            }
            if group.len() == 1 {
                let (k, v) = pairs_in[group[0] as usize];
                debug_assert!(crate::gpusim::mem::is_user_key(k));
                slots.set(group[0] as usize, self.upsert_under_lock(k, v, op, None));
            } else {
                // One shared scan of the group's common front-yard bucket
                // (one tag-block probe for the metadata variant).
                let mut free = if let Some(meta) = &self.fmeta {
                    tags.clear();
                    tags.extend(group.iter().map(|&i| tag16(pairs_in[i as usize].0)));
                    meta.scan_group(fb, &tags, strong, &mut per_tag).0
                } else {
                    group_keys.clear();
                    group_keys.extend(group.iter().map(|&i| pairs_in[i as usize].0));
                    self.front
                        .scan_bucket_group(fb, &group_keys, strong, &mut found)
                        .0
                };
                let mut local: Vec<(u64, usize)> = Vec::new();
                let mut fallback_keys: Vec<u64> = Vec::new();
                for (j, &i) in group.iter().enumerate() {
                    let (k, v) = pairs_in[i as usize];
                    debug_assert!(crate::gpusim::mem::is_user_key(k));
                    if let Some(&(_, slot)) = local.iter().find(|&&(lk, _)| lk == k) {
                        let (_, old) = self.front.pair_at(fb, slot, strong);
                        self.apply_existing(&self.front, fb, slot, old, v, op);
                        slots.set(i as usize, UpsertResult::Updated);
                        continue;
                    }
                    if fallback_keys.contains(&k) {
                        slots.set(i as usize, self.upsert_under_lock(k, v, op, None));
                        continue;
                    }
                    let front_hit = if self.fmeta.is_some() {
                        self.front.scan_slots(fb, per_tag[j].match_slots(), k, strong)
                    } else {
                        found[j]
                    };
                    if let Some((slot, _)) = front_hit {
                        if self.reclaim_if_expired_in(&self.front, fb, slot, v, None) {
                            local.push((k, slot));
                            slots.set(i as usize, UpsertResult::Inserted);
                            continue;
                        }
                        // Fresh value read: the shared scan may predate
                        // merges applied earlier in this group.
                        let (_, old) = self.front.pair_at(fb, slot, strong);
                        self.apply_existing(&self.front, fb, slot, old, v, op);
                        slots.set(i as usize, UpsertResult::Updated);
                        continue;
                    }
                    // Not in the front yard — the key may still live in
                    // the back yard (no early exit exists for iceberg).
                    let tag = if self.fmeta.is_some() { tag16(k) } else { 0 };
                    if let Some((bb, slot, old)) = self.locate_back(k, tag, strong) {
                        if self.reclaim_if_expired_in(&self.back, bb, slot, v, None) {
                            slots.set(i as usize, UpsertResult::Inserted);
                            continue;
                        }
                        self.apply_existing(&self.back, bb, slot, old, v, op);
                        slots.set(i as usize, UpsertResult::Updated);
                        continue;
                    }
                    // Absent: front yard first, from the shared free
                    // list; overflow to the back yard via the fallback.
                    if let Some(slot) = self.claim_front_from(fb, &mut free, k, v) {
                        self.stamp_fresh_in(&self.front, fb, slot, None);
                        self.live.fetch_add(1, Ordering::Relaxed);
                        local.push((k, slot));
                        slots.set(i as usize, UpsertResult::Inserted);
                        continue;
                    }
                    slots.set(i as usize, self.upsert_under_lock(k, v, op, None));
                    fallback_keys.push(k);
                }
            }
            if locking {
                self.locks.unlock(fb);
            }
        });
        slots.finish("IcebergHT::upsert_bulk");
    }

    fn query_bulk(&self, keys_in: &[u64], out: &mut Vec<Option<u64>>) {
        let base = out.len();
        out.resize(base + keys_in.len(), None);
        let mut slots = super::SlotWriter::new(&mut out[base..]);
        let buckets: Vec<usize> = keys_in.iter().map(|&k| self.front_bucket(k)).collect();
        let strong = self.mode.strong();
        let mut tags: Vec<u16> = Vec::new();
        let mut per_tag: Vec<MetaScan> = Vec::new();
        let mut found: Vec<Option<(usize, u64)>> = Vec::new();
        let mut group_keys: Vec<u64> = Vec::new();
        super::for_each_bucket_group(&buckets, |fb, group| {
            if group.len() == 1 {
                let i = group[0] as usize;
                slots.set(i, self.query(keys_in[i]));
                return;
            }
            if let Some(meta) = &self.fmeta {
                tags.clear();
                tags.extend(group.iter().map(|&i| tag16(keys_in[i as usize])));
                meta.scan_group(fb, &tags, strong, &mut per_tag);
            } else {
                group_keys.clear();
                group_keys.extend(group.iter().map(|&i| keys_in[i as usize]));
                self.front.scan_bucket_group(fb, &group_keys, strong, &mut found);
            }
            for (j, &i) in group.iter().enumerate() {
                let k = keys_in[i as usize];
                let front_hit = if self.fmeta.is_some() {
                    self.front.scan_slots(fb, per_tag[j].match_slots(), k, strong)
                } else {
                    found[j]
                };
                slots.set(
                    i as usize,
                    front_hit
                        .and_then(|(slot, v)| {
                            self.hit_live_in(&self.front, fb, slot).then_some(v)
                        })
                        .or_else(|| {
                            if front_hit.is_some() {
                                // Expired front-yard hit: a key lives in
                                // at most one yard, so don't fall back.
                                return None;
                            }
                            let tag = if self.fmeta.is_some() { tag16(k) } else { 0 };
                            self.locate_back(k, tag, strong).and_then(|(bb, slot, v)| {
                                self.hit_live_in(&self.back, bb, slot).then_some(v)
                            })
                        }),
                );
            }
        });
        slots.finish("IcebergHT::query_bulk");
    }

    fn erase_bulk(&self, keys_in: &[u64], out: &mut Vec<bool>) {
        let base = out.len();
        out.resize(base + keys_in.len(), false);
        let mut slots = super::SlotWriter::new(&mut out[base..]);
        let buckets: Vec<usize> = keys_in.iter().map(|&k| self.front_bucket(k)).collect();
        let locking = self.mode.locking();
        let strong = self.mode.strong();
        let mut tags: Vec<u16> = Vec::new();
        let mut per_tag: Vec<MetaScan> = Vec::new();
        let mut found: Vec<Option<(usize, u64)>> = Vec::new();
        let mut group_keys: Vec<u64> = Vec::new();
        super::for_each_bucket_group(&buckets, |fb, group| {
            if locking {
                self.locks.lock(fb);
            }
            if group.len() == 1 {
                let i = group[0] as usize;
                slots.set(i, self.erase_under_lock(keys_in[i]));
            } else {
                if self.fmeta.is_some() {
                    tags.clear();
                    tags.extend(group.iter().map(|&i| tag16(keys_in[i as usize])));
                    self.fmeta
                        .as_ref()
                        .unwrap()
                        .scan_group(fb, &tags, strong, &mut per_tag);
                } else {
                    group_keys.clear();
                    group_keys.extend(group.iter().map(|&i| keys_in[i as usize]));
                    self.front.scan_bucket_group(fb, &group_keys, strong, &mut found);
                }
                let mut processed: Vec<u64> = Vec::new();
                for (j, &i) in group.iter().enumerate() {
                    let k = keys_in[i as usize];
                    if processed.contains(&k) {
                        slots.set(i as usize, self.erase_under_lock(k));
                        continue;
                    }
                    processed.push(k);
                    let front_hit = if self.fmeta.is_some() {
                        self.front.scan_slots(fb, per_tag[j].match_slots(), k, strong)
                    } else {
                        found[j]
                    };
                    let hit = if let Some((slot, _)) = front_hit {
                        let was_live = !self.is_expired_in(&self.front, fb, slot);
                        self.kill_in(&self.front, fb, slot, k);
                        was_live
                    } else {
                        let tag = if self.fmeta.is_some() { tag16(k) } else { 0 };
                        match self.locate_back(k, tag, strong) {
                            Some((bb, slot, _)) => {
                                let was_live = !self.is_expired_in(&self.back, bb, slot);
                                self.kill_in(&self.back, bb, slot, k);
                                was_live
                            }
                            None => false,
                        }
                    };
                    slots.set(i as usize, hit);
                }
            }
            if locking {
                self.locks.unlock(fb);
            }
        });
        slots.finish("IcebergHT::erase_bulk");
    }

    fn num_buckets(&self) -> usize {
        self.front.num_buckets
    }

    fn primary_bucket(&self, key: u64) -> usize {
        self.front_bucket(key)
    }

    fn capacity(&self) -> usize {
        self.front.num_buckets * self.front.bucket_size
            + self.back.num_buckets * self.back.bucket_size
    }

    fn len(&self) -> usize {
        self.live.load(Ordering::Relaxed) as usize
    }

    fn device_bytes(&self) -> usize {
        self.front.device_bytes()
            + self.back.device_bytes()
            + self.fmeta.as_ref().map_or(0, |m| m.device_bytes())
            + self.bmeta.as_ref().map_or(0, |m| m.device_bytes())
            + self.locks.bytes()
            + self.life.as_ref().map_or(0, |l| l.device_bytes())
    }

    fn name(&self) -> &'static str {
        if self.fmeta.is_some() {
            "IcebergHT(M)"
        } else {
            "IcebergHT"
        }
    }

    fn is_stable(&self) -> bool {
        true
    }

    fn fetch_add_in_place(&self, key: u64, v: u64) -> bool {
        match self.locate(key, self.mode.strong()) {
            Some((pairs, b, slot, _)) => {
                if self.is_expired_in(pairs, b, slot) {
                    return false;
                }
                pairs.value_fetch_add(b, slot, v);
                true
            }
            None => false,
        }
    }

    fn fetch_add_f64_in_place(&self, key: u64, v: f64) -> bool {
        match self.locate(key, self.mode.strong()) {
            Some((pairs, b, slot, _)) => {
                if self.is_expired_in(pairs, b, slot) {
                    return false;
                }
                pairs.value_fetch_add_f64(b, slot, v);
                true
            }
            None => false,
        }
    }

    fn for_each_entry(&self, f: &mut dyn FnMut(u64, u64)) {
        match &self.life {
            Some(l) => {
                let fbs = self.front.bucket_size;
                let back_base = self.front.num_buckets * fbs;
                let bbs = self.back.bucket_size;
                self.front.for_each_live_indexed(|b, s, k, v| {
                    if !l.is_expired_at(b * fbs + s) {
                        f(k, v);
                    }
                });
                self.back.for_each_live_indexed(|b, s, k, v| {
                    if !l.is_expired_at(back_base + b * bbs + s) {
                        f(k, v);
                    }
                });
            }
            None => {
                self.front.for_each_live(|k, v| f(k, v));
                self.back.for_each_live(|k, v| f(k, v));
            }
        }
    }

    fn count_copies(&self, key: u64) -> usize {
        self.front.count_copies(key) + self.back.count_copies(key)
    }

    fn supports_ttl(&self) -> bool {
        self.life.is_some()
    }

    fn sweep_expired(&self, max_buckets: usize) -> usize {
        let Some(l) = &self.life else { return 0 };
        // The sweep ring spans BOTH yards: buckets [0, nf) are the front
        // yard, [nf, nf + nb) the back yard.
        let nf = self.front.num_buckets;
        let total = nf + self.back.num_buckets;
        let n = max_buckets.min(total);
        if n == 0 {
            return 0;
        }
        let start = self.sweep_cursor.fetch_add(n, Ordering::Relaxed) % total;
        let mut victims: Vec<u64> = Vec::new();
        for off in 0..n {
            let rb = (start + off) % total;
            let (pairs, b, base, bs) = if rb < nf {
                (&self.front, rb, 0, self.front.bucket_size)
            } else {
                (&self.back, rb - nf, nf * self.front.bucket_size, self.back.bucket_size)
            };
            for s in 0..bs {
                let k = pairs.key_at(b, s, false);
                if crate::gpusim::mem::is_user_key(k) && l.is_expired_at(base + b * bs + s) {
                    victims.push(k);
                }
            }
        }
        let mut reclaimed = 0;
        for k in victims {
            if self.erase_expired(k) {
                reclaimed += 1;
            }
        }
        self.swept.fetch_add(reclaimed as u64, Ordering::Relaxed);
        reclaimed
    }

    fn swept_expired(&self) -> u64 {
        self.swept.load(Ordering::Relaxed)
    }

    fn entry_frequency(&self, key: u64) -> Option<u8> {
        let l = self.life.as_ref()?;
        let (pairs, b, slot, _) = self.locate(key, self.mode.strong())?;
        let ls = self.lifeslot_in(pairs, b, slot);
        (!l.is_expired_at(ls)).then(|| l.freq_at(ls))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tables::test_support::*;

    fn plain(slots: usize) -> IcebergHt {
        IcebergHt::new(TableConfig::new(slots).with_geometry(32, 8), false)
    }

    fn meta(slots: usize) -> IcebergHt {
        IcebergHt::new(TableConfig::new(slots).with_geometry(32, 4), true)
    }

    fn plain_ttl(slots: usize, cfg: &crate::tables::LifecycleConfig) -> IcebergHt {
        IcebergHt::new(
            TableConfig::new(slots)
                .with_geometry(32, 8)
                .with_lifecycle(cfg.clone()),
            false,
        )
    }

    fn meta_ttl(slots: usize, cfg: &crate::tables::LifecycleConfig) -> IcebergHt {
        IcebergHt::new(
            TableConfig::new(slots)
                .with_geometry(32, 4)
                .with_lifecycle(cfg.clone()),
            true,
        )
    }

    #[test]
    fn basic_crud() {
        check_basic_crud(&plain(2048));
        check_basic_crud(&meta(2048));
    }

    #[test]
    fn fills_to_90_percent() {
        check_fill_to(&plain(8192), 0.90);
        check_fill_to(&meta(8192), 0.90);
    }

    #[test]
    fn upsert_policies() {
        check_upsert_policies(&plain(2048));
        check_upsert_policies(&meta(2048));
    }

    #[test]
    fn aging_churn() {
        check_aging_churn(&plain(4096), 40);
        check_aging_churn(&meta(4096), 40);
    }

    #[test]
    fn concurrent_no_duplicates() {
        check_concurrent_no_duplicates(std::sync::Arc::new(plain(8192)));
        check_concurrent_no_duplicates(std::sync::Arc::new(meta(8192)));
    }

    #[test]
    fn concurrent_mixed() {
        check_concurrent_mixed(std::sync::Arc::new(plain(8192)));
    }

    #[test]
    fn in_place_accumulate() {
        check_fetch_add_in_place(&plain(2048));
        check_fetch_add_in_place(&meta(2048));
    }

    #[test]
    fn oracle_equivalence() {
        check_vs_oracle(&plain(4096), 0x31);
        check_vs_oracle(&meta(4096), 0x32);
    }

    #[test]
    fn front_yard_holds_low_load_keys() {
        let t = plain(8192);
        let ks = keys(64, 0x1CE);
        for &k in &ks {
            t.upsert(k, 1, &UpsertOp::InsertIfUnique);
        }
        for &k in &ks {
            let fb = t.front_bucket(k);
            assert!(
                t.front.scan_bucket(fb, k, true).found.is_some(),
                "low-load key must sit in the front yard"
            );
        }
    }

    #[test]
    fn bulk_matches_scalar_twin() {
        check_bulk_parity(&plain(2048), &plain(2048), 0x33);
        check_bulk_parity(&meta(2048), &meta(2048), 0x34);
    }

    #[test]
    fn bulk_parity_with_backyard_overflow() {
        // Tiny front yards overflow into the back yard; the grouped path
        // must keep finding and erasing back-yard residents.
        check_bulk_parity(&plain(256), &plain(256), 0x35);
        check_bulk_parity(&meta(256), &meta(256), 0x36);
    }

    #[test]
    fn bulk_concurrent_no_duplicates() {
        check_bulk_concurrent_no_duplicates(std::sync::Arc::new(plain(8192)));
        check_bulk_concurrent_no_duplicates(std::sync::Arc::new(meta(8192)));
    }

    #[test]
    fn ttl_semantics_plain_and_meta() {
        let cfg = crate::tables::LifecycleConfig::new(4);
        check_ttl_semantics(&plain_ttl(2048, &cfg), &cfg);
        let cfg = crate::tables::LifecycleConfig::new(4);
        check_ttl_semantics(&meta_ttl(2048, &cfg), &cfg);
    }

    #[test]
    fn sweep_matches_expiry_oracle() {
        let cfg = crate::tables::LifecycleConfig::new(1);
        check_sweep_vs_oracle(&plain_ttl(2048, &cfg), &cfg);
        let cfg = crate::tables::LifecycleConfig::new(1);
        check_sweep_vs_oracle(&meta_ttl(2048, &cfg), &cfg);
    }

    #[test]
    fn sweep_reclaims_backyard_corpses() {
        // Tiny front yard: mortal keys overflow into the back yard, and
        // the combined-ring sweep must still reclaim them.
        let cfg = crate::tables::LifecycleConfig::new(1);
        let t = plain_ttl(256, &cfg);
        let front_cap = t.front.num_buckets * t.front.bucket_size;
        let ks = keys(front_cap + 40, 0x37);
        for &k in &ks {
            t.upsert_ttl(k, 1, 2, &UpsertOp::InsertIfUnique);
        }
        assert!(
            ks.iter().any(|&k| t.back.count_copies(k) == 1),
            "setup must push mortals into the back yard"
        );
        cfg.clock.advance(2);
        let total = t.front.num_buckets + t.back.num_buckets;
        let mut reclaimed = 0;
        for _ in 0..(2 * total).div_ceil(8) {
            reclaimed += t.sweep_expired(8);
        }
        assert_eq!(reclaimed, t.swept_expired() as usize);
        for &k in &ks {
            assert_eq!(t.count_copies(k), 0, "corpse survived the sweep");
        }
        assert_eq!(t.len(), 0);
    }

    #[test]
    fn bulk_ttl_parity_both_variants() {
        let cfg = crate::tables::LifecycleConfig::new(2);
        check_bulk_ttl_parity(&plain_ttl(2048, &cfg), &plain_ttl(2048, &cfg), &cfg, 0x38);
        let cfg = crate::tables::LifecycleConfig::new(2);
        check_bulk_ttl_parity(&meta_ttl(2048, &cfg), &meta_ttl(2048, &cfg), &cfg, 0x39);
    }

    #[test]
    fn meta_frequency_bumps_add_zero_probe_lines() {
        let cfg = crate::tables::LifecycleConfig::new(4);
        check_query_line_parity(&meta(4096), &meta_ttl(4096, &cfg), &cfg, 0x3A);
    }

    #[test]
    fn lifecycle_off_is_free() {
        let t = plain(2048);
        assert!(!t.supports_ttl());
        assert_eq!(t.sweep_expired(64), 0);
        assert_eq!(t.entry_frequency(77), None);
    }

    #[test]
    fn overflow_goes_to_backyard() {
        // Tiny front yard overfilled past its slot count: overflow is
        // forced into the back yard and keys must remain findable.
        let t = IcebergHt::new(TableConfig::new(256).with_geometry(32, 8), false);
        let front_cap = t.front.num_buckets * t.front.bucket_size;
        let ks = keys(front_cap + 40, 0xBEE);
        let mut inserted = vec![];
        for &k in &ks {
            if t.upsert(k, k ^ 7, &UpsertOp::InsertIfUnique) == UpsertResult::Inserted {
                inserted.push(k);
            }
        }
        assert!(inserted.len() > front_cap, "must exceed front-yard capacity");
        for &k in &inserted {
            assert_eq!(t.query(k), Some(k ^ 7));
        }
        // Some keys must actually be in the back yard.
        let in_back = inserted
            .iter()
            .filter(|&&k| t.back.count_copies(k) == 1)
            .count();
        assert!(in_back > 0, "no key overflowed to the back yard");
    }
}
