//! SlabHash-like baseline — INTENTIONALLY UNSYNCHRONIZED (paper §4.1).
//!
//! Reproduces the concurrency bug the paper demonstrates in SlabHash [3]:
//! upserts rely *solely* on atomic CAS with no external (lock-based)
//! synchronization between threads operating on the same key. With
//! associativity two (a primary and an alternate bucket), the Figure 4.1
//! interleaving — T1 probes past the full primary, T3 deletes from the
//! primary, T2 inserts into the freed slot, T1 completes in the alternate
//! — leaves TWO copies of the key in the table, even though every
//! individual memory operation is atomic. `insert_unique` here mirrors
//! SlabHash's `insertPairUnique` (query-then-claim).
//!
//! The table emits [`RaceEvent`]s at the §4.1-relevant points so the
//! adversarial benchmark can force the schedule deterministically; it is
//! excluded from every performance benchmark exactly as the paper
//! excludes SlabHash ("fail the correctness test").

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use super::common::{bucket_count_for, Pairs};
use super::lifecycle::LifecycleSlots;
use super::{ConcurrencyMode, ConcurrentMap, TableConfig, UpsertOp, UpsertResult};
use crate::gpusim::mem::is_user_key;
use crate::gpusim::race::RaceEvent;
use crate::hash::{hash1, hash2};

pub struct SlabHashLike {
    pairs: Pairs,
    mode: ConcurrencyMode,
    hook: std::sync::Arc<dyn crate::gpusim::race::RaceHook>,
    live: AtomicU64,
    /// TTL + frequency codes (standalone side array; this baseline has
    /// no metadata path to colocate into).
    life: Option<LifecycleSlots>,
    sweep_cursor: AtomicUsize,
    swept: AtomicU64,
}

impl SlabHashLike {
    pub fn new(cfg: TableConfig) -> Self {
        let nb = bucket_count_for(cfg.slots, cfg.bucket_size);
        let life = cfg
            .lifecycle
            .clone()
            .map(|lc| LifecycleSlots::standalone(lc, nb * cfg.bucket_size));
        Self {
            pairs: Pairs::new(nb, cfg.bucket_size, cfg.tile_size),
            mode: cfg.mode,
            hook: cfg.hook,
            live: AtomicU64::new(0),
            life,
            sweep_cursor: AtomicUsize::new(0),
            swept: AtomicU64::new(0),
        }
    }

    #[inline(always)]
    fn buckets_of(&self, key: u64) -> [usize; 2] {
        let mask = self.pairs.mask();
        [(hash1(key) & mask) as usize, (hash2(key) & mask) as usize]
    }

    #[inline(always)]
    fn lifeslot(&self, b: usize, slot: usize) -> usize {
        b * self.pairs.bucket_size + slot
    }

    #[inline]
    fn is_expired(&self, b: usize, slot: usize) -> bool {
        self.life
            .as_ref()
            .is_some_and(|l| l.is_expired_at(self.lifeslot(b, slot)))
    }

    #[inline]
    fn stamp_fresh(&self, b: usize, slot: usize, ttl: Option<u64>) {
        if let Some(l) = &self.life {
            l.fresh(self.lifeslot(b, slot), ttl);
        }
    }

    /// Claim + publish in one bucket; `None` = bucket full,
    /// `Some(Ok(slot))` = inserted there, `Some(Err(slot))` = key
    /// already present at `slot`.
    fn try_bucket(
        &self,
        b: usize,
        key: u64,
        val: u64,
        strong: bool,
    ) -> Option<Result<usize, usize>> {
        loop {
            let r = self.pairs.scan_bucket(b, key, strong);
            if let Some((slot, _)) = r.found {
                return Some(Err(slot));
            }
            let slot = r.reusable()?;
            self.hook.on_event(RaceEvent::BeforeClaim { key, bucket: b });
            if self.pairs.try_claim(b, slot, true) {
                self.pairs.publish(b, slot, key, val);
                return Some(Ok(slot));
            }
        }
    }

    /// `insertPairUnique` body shared by `upsert` / `upsert_ttl`. On a
    /// present key the value is NOT merged (SlabHash fidelity) — but an
    /// EXPIRED resident is reclaimed in place as a fresh insert, and a
    /// live one has its deadline refreshed when a TTL is supplied.
    fn upsert_with_ttl(&self, key: u64, val: u64, ttl: Option<u64>) -> UpsertResult {
        let strong = self.mode.strong();
        let [b1, b2] = self.buckets_of(key);
        let present = |b: usize, slot: usize| -> UpsertResult {
            if self.is_expired(b, slot) {
                self.pairs.value_store(b, slot, val);
                self.stamp_fresh(b, slot, ttl);
                return UpsertResult::Inserted;
            }
            if ttl.is_some() {
                if let Some(l) = &self.life {
                    l.refresh(self.lifeslot(b, slot), ttl);
                }
            }
            UpsertResult::Updated
        };
        match self.try_bucket(b1, key, val, strong) {
            Some(Ok(slot)) => {
                self.stamp_fresh(b1, slot, ttl);
                self.live.fetch_add(1, Ordering::Relaxed);
                return UpsertResult::Inserted;
            }
            Some(Err(slot)) => return present(b1, slot),
            None => {}
        }
        // Primary full → move to the alternate. THIS is the §4.1 window:
        // a concurrent delete in b1 plus a concurrent insert of the same
        // key can now land a second copy in b1 while we insert into b2.
        self.hook
            .on_event(RaceEvent::PrimaryFullMovingOn { key, bucket: b1 });
        match self.try_bucket(b2, key, val, strong) {
            Some(Ok(slot)) => {
                self.stamp_fresh(b2, slot, ttl);
                self.live.fetch_add(1, Ordering::Relaxed);
                UpsertResult::Inserted
            }
            Some(Err(slot)) => present(b2, slot),
            None => UpsertResult::Full,
        }
    }

    /// Sweep reclaim: atomicCAS delete iff still present and expired.
    fn erase_expired(&self, key: u64) -> bool {
        let strong = self.mode.strong();
        for b in self.buckets_of(key) {
            if let Some((slot, _)) = self.pairs.scan_bucket(b, key, strong).found {
                if !self.is_expired(b, slot) {
                    return false;
                }
                let kidx = self.pairs.kidx(b, slot);
                if self
                    .pairs
                    .mem()
                    .cas(kidx, key, super::common::KEY_TOMBSTONE)
                    .is_ok()
                {
                    if let Some(l) = &self.life {
                        l.clear(self.lifeslot(b, slot));
                    }
                    self.live.fetch_sub(1, Ordering::Relaxed);
                    self.hook.on_event(RaceEvent::AfterDelete { key, bucket: b });
                    return true;
                }
            }
        }
        false
    }
}

impl ConcurrentMap for SlabHashLike {
    /// `insertPairUnique` semantics: query-then-claim per bucket, atomics
    /// only, NO key-level serialization. Racy by construction.
    fn upsert(&self, key: u64, val: u64, _op: &UpsertOp) -> UpsertResult {
        self.upsert_with_ttl(key, val, None)
    }

    fn upsert_ttl(&self, key: u64, val: u64, ttl_ticks: u64, _op: &UpsertOp) -> UpsertResult {
        self.upsert_with_ttl(key, val, self.life.is_some().then_some(ttl_ticks))
    }

    fn query(&self, key: u64) -> Option<u64> {
        let strong = self.mode.strong();
        for b in self.buckets_of(key) {
            if let Some((slot, v)) = self.pairs.scan_bucket(b, key, strong).found {
                let live = match &self.life {
                    Some(l) => l.on_hit(self.lifeslot(b, slot)),
                    None => true,
                };
                return live.then_some(v);
            }
        }
        None
    }

    fn erase(&self, key: u64) -> bool {
        let strong = self.mode.strong();
        for b in self.buckets_of(key) {
            if let Some((slot, _)) = self.pairs.scan_bucket(b, key, strong).found {
                let was_live = !self.is_expired(b, slot);
                // atomicCAS delete, no lock.
                let kidx = self.pairs.kidx(b, slot);
                if self
                    .pairs
                    .mem()
                    .cas(kidx, key, super::common::KEY_TOMBSTONE)
                    .is_ok()
                {
                    if let Some(l) = &self.life {
                        l.clear(self.lifeslot(b, slot));
                    }
                    self.live.fetch_sub(1, Ordering::Relaxed);
                    self.hook.on_event(RaceEvent::AfterDelete { key, bucket: b });
                    return was_live;
                }
            }
        }
        false
    }

    fn num_buckets(&self) -> usize {
        self.pairs.num_buckets
    }

    fn primary_bucket(&self, key: u64) -> usize {
        self.buckets_of(key)[0]
    }

    fn capacity(&self) -> usize {
        self.pairs.num_buckets * self.pairs.bucket_size
    }

    fn len(&self) -> usize {
        self.live.load(Ordering::Relaxed) as usize
    }

    fn device_bytes(&self) -> usize {
        self.pairs.device_bytes() + self.life.as_ref().map_or(0, |l| l.device_bytes())
    }

    fn name(&self) -> &'static str {
        "SlabHash-like"
    }

    fn is_stable(&self) -> bool {
        true
    }

    fn for_each_entry(&self, f: &mut dyn FnMut(u64, u64)) {
        match &self.life {
            Some(l) => {
                let bsz = self.pairs.bucket_size;
                self.pairs.for_each_live_indexed(|b, s, k, v| {
                    if !l.is_expired_at(b * bsz + s) {
                        f(k, v);
                    }
                });
            }
            None => self.pairs.for_each_live(|k, v| f(k, v)),
        }
    }

    fn count_copies(&self, key: u64) -> usize {
        self.pairs.count_copies(key)
    }

    fn supports_ttl(&self) -> bool {
        self.life.is_some()
    }

    fn sweep_expired(&self, max_buckets: usize) -> usize {
        let Some(l) = &self.life else { return 0 };
        let nb = self.pairs.num_buckets;
        let n = max_buckets.min(nb);
        if n == 0 {
            return 0;
        }
        let start = self.sweep_cursor.fetch_add(n, Ordering::Relaxed) % nb;
        let mut victims: Vec<u64> = Vec::new();
        for off in 0..n {
            let b = (start + off) % nb;
            for s in 0..self.pairs.bucket_size {
                let k = self.pairs.key_at(b, s, false);
                if is_user_key(k) && l.is_expired_at(self.lifeslot(b, s)) {
                    victims.push(k);
                }
            }
        }
        let mut reclaimed = 0;
        for k in victims {
            if self.erase_expired(k) {
                reclaimed += 1;
            }
        }
        self.swept.fetch_add(reclaimed as u64, Ordering::Relaxed);
        reclaimed
    }

    fn swept_expired(&self) -> u64 {
        self.swept.load(Ordering::Relaxed)
    }

    fn entry_frequency(&self, key: u64) -> Option<u8> {
        let l = self.life.as_ref()?;
        let strong = self.mode.strong();
        for b in self.buckets_of(key) {
            if let Some((slot, _)) = self.pairs.scan_bucket(b, key, strong).found {
                let ls = self.lifeslot(b, slot);
                return (!l.is_expired_at(ls)).then(|| l.freq_at(ls));
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tables::test_support::*;

    fn table(slots: usize) -> SlabHashLike {
        SlabHashLike::new(TableConfig::new(slots).with_geometry(8, 4))
    }

    #[test]
    fn sequential_crud_is_fine() {
        // Without adversarial interleavings the table behaves correctly —
        // that's exactly why the bug went unnoticed.
        check_basic_crud(&table(2048));
    }

    #[test]
    fn sequential_fill() {
        // 2-choice without displacement tops out well below the stable
        // designs — 70% is reliably reachable, 90% is not.
        check_fill_to(&table(8192), 0.70);
    }

    fn table_ttl(slots: usize, cfg: &crate::tables::LifecycleConfig) -> SlabHashLike {
        SlabHashLike::new(
            TableConfig::new(slots)
                .with_geometry(8, 4)
                .with_lifecycle(cfg.clone()),
        )
    }

    #[test]
    fn ttl_expire_reclaim_and_refresh() {
        // Tailored TTL suite: the shared check_ttl_semantics asserts
        // merge-on-update, which insertPairUnique deliberately lacks —
        // everything else (expire-on-read, reclaim, refresh, frequency)
        // must still hold.
        let cfg = crate::tables::LifecycleConfig::new(4);
        let q = cfg.quantum;
        let t = table_ttl(2048, &cfg);
        let ks = keys(4, 0x61);
        assert_eq!(
            t.upsert_ttl(ks[0], 1, 3 * q, &UpsertOp::InsertIfUnique),
            UpsertResult::Inserted
        );
        assert_eq!(t.query(ks[0]), Some(1));
        cfg.clock.advance(3 * q);
        assert_eq!(t.query(ks[0]), None, "expire-on-read");
        assert_eq!(t.entry_frequency(ks[0]), None);
        // Reclaim in place: fresh insert, single physical copy.
        assert_eq!(
            t.upsert(ks[0], 7, &UpsertOp::InsertIfUnique),
            UpsertResult::Inserted
        );
        assert_eq!(t.query(ks[0]), Some(7));
        assert_eq!(t.count_copies(ks[0]), 1);
        // Refresh extends the deadline and keeps the counter.
        assert_eq!(
            t.upsert_ttl(ks[1], 9, 2 * q, &UpsertOp::InsertIfUnique),
            UpsertResult::Inserted
        );
        assert!(t.query(ks[1]).is_some());
        assert_eq!(
            t.upsert_ttl(ks[1], 9, 5 * q, &UpsertOp::InsertIfUnique),
            UpsertResult::Updated
        );
        cfg.clock.advance(3 * q);
        assert!(t.query(ks[1]).is_some(), "refreshed TTL outlives original");
        assert_eq!(t.entry_frequency(ks[1]), Some(2));
        cfg.clock.advance(2 * q);
        assert_eq!(t.query(ks[1]), None);
        // Erase of a corpse reports absent but reclaims the slot.
        assert!(!t.erase(ks[1]));
        assert_eq!(t.count_copies(ks[1]), 0);
    }

    #[test]
    fn sweep_matches_expiry_oracle() {
        let cfg = crate::tables::LifecycleConfig::new(1);
        check_sweep_vs_oracle(&table_ttl(2048, &cfg), &cfg);
    }

    #[test]
    fn bulk_ttl_parity() {
        let cfg = crate::tables::LifecycleConfig::new(2);
        check_bulk_ttl_parity(&table_ttl(2048, &cfg), &table_ttl(2048, &cfg), &cfg, 0x62);
    }

    #[test]
    fn lifecycle_off_is_free() {
        let t = table(1024);
        assert!(!t.supports_ttl());
        assert_eq!(t.sweep_expired(64), 0);
        assert_eq!(t.entry_frequency(42), None);
    }

    // The demonstration that it is NOT correct lives in the adversarial
    // benchmark (rust/tests/adversarial.rs + bench_adversarial), where the
    // Fig 4.1 schedule forces a duplicate key.
}
