//! SlabHash-like baseline — INTENTIONALLY UNSYNCHRONIZED (paper §4.1).
//!
//! Reproduces the concurrency bug the paper demonstrates in SlabHash [3]:
//! upserts rely *solely* on atomic CAS with no external (lock-based)
//! synchronization between threads operating on the same key. With
//! associativity two (a primary and an alternate bucket), the Figure 4.1
//! interleaving — T1 probes past the full primary, T3 deletes from the
//! primary, T2 inserts into the freed slot, T1 completes in the alternate
//! — leaves TWO copies of the key in the table, even though every
//! individual memory operation is atomic. `insert_unique` here mirrors
//! SlabHash's `insertPairUnique` (query-then-claim).
//!
//! The table emits [`RaceEvent`]s at the §4.1-relevant points so the
//! adversarial benchmark can force the schedule deterministically; it is
//! excluded from every performance benchmark exactly as the paper
//! excludes SlabHash ("fail the correctness test").

use std::sync::atomic::{AtomicU64, Ordering};

use super::common::{bucket_count_for, Pairs};
use super::{ConcurrencyMode, ConcurrentMap, TableConfig, UpsertOp, UpsertResult};
use crate::gpusim::race::RaceEvent;
use crate::hash::{hash1, hash2};

pub struct SlabHashLike {
    pairs: Pairs,
    mode: ConcurrencyMode,
    hook: std::sync::Arc<dyn crate::gpusim::race::RaceHook>,
    live: AtomicU64,
}

impl SlabHashLike {
    pub fn new(cfg: TableConfig) -> Self {
        let nb = bucket_count_for(cfg.slots, cfg.bucket_size);
        Self {
            pairs: Pairs::new(nb, cfg.bucket_size, cfg.tile_size),
            mode: cfg.mode,
            hook: cfg.hook,
            live: AtomicU64::new(0),
        }
    }

    #[inline(always)]
    fn buckets_of(&self, key: u64) -> [usize; 2] {
        let mask = self.pairs.mask();
        [(hash1(key) & mask) as usize, (hash2(key) & mask) as usize]
    }

    /// Claim + publish in one bucket; `None` = bucket full, `Some(true)` =
    /// inserted, `Some(false)` = key already present.
    fn try_bucket(&self, b: usize, key: u64, val: u64, strong: bool) -> Option<bool> {
        loop {
            let r = self.pairs.scan_bucket(b, key, strong);
            if r.found.is_some() {
                return Some(false);
            }
            let slot = r.reusable()?;
            self.hook.on_event(RaceEvent::BeforeClaim { key, bucket: b });
            if self.pairs.try_claim(b, slot, true) {
                self.pairs.publish(b, slot, key, val);
                return Some(true);
            }
        }
    }
}

impl ConcurrentMap for SlabHashLike {
    /// `insertPairUnique` semantics: query-then-claim per bucket, atomics
    /// only, NO key-level serialization. Racy by construction.
    fn upsert(&self, key: u64, val: u64, _op: &UpsertOp) -> UpsertResult {
        let strong = self.mode.strong();
        let [b1, b2] = self.buckets_of(key);
        match self.try_bucket(b1, key, val, strong) {
            Some(true) => {
                self.live.fetch_add(1, Ordering::Relaxed);
                return UpsertResult::Inserted;
            }
            Some(false) => return UpsertResult::Updated,
            None => {}
        }
        // Primary full → move to the alternate. THIS is the §4.1 window:
        // a concurrent delete in b1 plus a concurrent insert of the same
        // key can now land a second copy in b1 while we insert into b2.
        self.hook
            .on_event(RaceEvent::PrimaryFullMovingOn { key, bucket: b1 });
        match self.try_bucket(b2, key, val, strong) {
            Some(true) => {
                self.live.fetch_add(1, Ordering::Relaxed);
                UpsertResult::Inserted
            }
            Some(false) => UpsertResult::Updated,
            None => UpsertResult::Full,
        }
    }

    fn query(&self, key: u64) -> Option<u64> {
        let strong = self.mode.strong();
        for b in self.buckets_of(key) {
            if let Some((_, v)) = self.pairs.scan_bucket(b, key, strong).found {
                return Some(v);
            }
        }
        None
    }

    fn erase(&self, key: u64) -> bool {
        let strong = self.mode.strong();
        for b in self.buckets_of(key) {
            if let Some((slot, _)) = self.pairs.scan_bucket(b, key, strong).found {
                // atomicCAS delete, no lock.
                let kidx = self.pairs.kidx(b, slot);
                if self
                    .pairs
                    .mem()
                    .cas(kidx, key, super::common::KEY_TOMBSTONE)
                    .is_ok()
                {
                    self.live.fetch_sub(1, Ordering::Relaxed);
                    self.hook.on_event(RaceEvent::AfterDelete { key, bucket: b });
                    return true;
                }
            }
        }
        false
    }

    fn num_buckets(&self) -> usize {
        self.pairs.num_buckets
    }

    fn primary_bucket(&self, key: u64) -> usize {
        self.buckets_of(key)[0]
    }

    fn capacity(&self) -> usize {
        self.pairs.num_buckets * self.pairs.bucket_size
    }

    fn len(&self) -> usize {
        self.live.load(Ordering::Relaxed) as usize
    }

    fn device_bytes(&self) -> usize {
        self.pairs.device_bytes()
    }

    fn name(&self) -> &'static str {
        "SlabHash-like"
    }

    fn is_stable(&self) -> bool {
        true
    }

    fn for_each_entry(&self, f: &mut dyn FnMut(u64, u64)) {
        self.pairs.for_each_live(|k, v| f(k, v));
    }

    fn count_copies(&self, key: u64) -> usize {
        self.pairs.count_copies(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tables::test_support::*;

    fn table(slots: usize) -> SlabHashLike {
        SlabHashLike::new(TableConfig::new(slots).with_geometry(8, 4))
    }

    #[test]
    fn sequential_crud_is_fine() {
        // Without adversarial interleavings the table behaves correctly —
        // that's exactly why the bug went unnoticed.
        check_basic_crud(&table(2048));
    }

    #[test]
    fn sequential_fill() {
        // 2-choice without displacement tops out well below the stable
        // designs — 70% is reliably reachable, 90% is not.
        check_fill_to(&table(8192), 0.70);
    }

    // The demonstration that it is NOT correct lives in the adversarial
    // benchmark (rust/tests/adversarial.rs + bench_adversarial), where the
    // Fig 4.1 schedule forces a duplicate key.
}
