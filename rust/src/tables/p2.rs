//! P2HT / P2HT(M) — power-of-two-choice hashing (paper §2.2, §5).
//!
//! Each key hashes to two candidate buckets (32 KV pairs each, spanning 4
//! cache lines) and is inserted into the less-loaded one. The *shortcut*
//! optimization inserts directly into the primary bucket without loading
//! the alternate while the primary's fill is below 75% — this is what
//! gives P2HT its fast low-load insertions (paper §6.3: fastest until 35%
//! load factor).
//!
//! Queries must always consider both buckets (a key placed in the
//! alternate stays there even after the primary drains — stability), so
//! a plain negative query costs up to 8 line probes while the metadata
//! variant answers most negatives from the two 64-byte tag blocks
//! (Table 5.1: 8.01 → 2.01 aging negative probes).

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use super::common::{bucket_count_for, FreeSlots, Pairs};
use super::lifecycle::LifecycleSlots;
use super::meta::{MetaArray, MetaScan};
use super::{ConcurrencyMode, ConcurrentMap, TableConfig, UpsertOp, UpsertResult};
use crate::gpusim::race::RaceEvent;
use crate::gpusim::LockArray;
use crate::hash::{hash1, hash2, tag16};

/// Shortcut threshold (fraction of bucket_size).
const SHORTCUT_FILL: f64 = 0.75;

pub struct P2Ht {
    pairs: Pairs,
    meta: Option<MetaArray>,
    locks: LockArray,
    mode: ConcurrencyMode,
    hook: std::sync::Arc<dyn crate::gpusim::race::RaceHook>,
    live: AtomicU64,
    shortcut_limit: usize,
    /// Sticky per-bucket overflow bits: bit `b` is set once any key whose
    /// *primary* bucket is `b` has been placed in its alternate. While the
    /// bit is clear, every key of `b` provably lives in `b`, which makes
    /// the shortcut duplicate-check (and negative-query early exit) sound
    /// even under churn — deletions never clear the bit.
    overflow: Box<[AtomicU64]>,
    /// TTL + frequency codes (flat `bucket * bucket_size + slot`);
    /// colocated in the padded MetaArray region for the (M) variant,
    /// standalone for the plain variant.
    life: Option<LifecycleSlots>,
    sweep_cursor: AtomicUsize,
    swept: AtomicU64,
}

/// Per-bucket view produced by one scan, shared by the plain and metadata
/// paths so placement logic is written once.
struct BucketView {
    found: Option<(usize, u64)>,
    reusable: Option<usize>,
    fill: usize,
}

impl P2Ht {
    pub fn new(cfg: TableConfig, with_meta: bool) -> Self {
        Self::with_shortcut(cfg, with_meta, true)
    }

    /// `shortcut = false` disables the §2.2 shortcutting optimization
    /// (ablation: every insert loads and compares both buckets).
    pub fn with_shortcut(cfg: TableConfig, with_meta: bool, shortcut: bool) -> Self {
        let nb = bucket_count_for(cfg.slots, cfg.bucket_size);
        let pairs = Pairs::new(nb, cfg.bucket_size, cfg.tile_size);
        let meta = with_meta.then(|| {
            if cfg.lifecycle.is_some() {
                MetaArray::with_lifecycle_region(nb, cfg.bucket_size)
            } else {
                MetaArray::new(nb, cfg.bucket_size)
            }
        });
        let life = cfg.lifecycle.clone().map(|lc| {
            if with_meta {
                LifecycleSlots::colocated(lc, nb * cfg.bucket_size)
            } else {
                LifecycleSlots::standalone(lc, nb * cfg.bucket_size)
            }
        });
        let shortcut_limit = if shortcut {
            (cfg.bucket_size as f64 * SHORTCUT_FILL) as usize
        } else {
            0 // fill < 0 is impossible → shortcut never taken
        };
        let mut ov = Vec::with_capacity(nb.div_ceil(64));
        ov.resize_with(nb.div_ceil(64), || AtomicU64::new(0));
        Self {
            pairs,
            meta,
            locks: LockArray::new(nb),
            mode: cfg.mode,
            hook: cfg.hook,
            live: AtomicU64::new(0),
            shortcut_limit,
            overflow: ov.into_boxed_slice(),
            life,
            sweep_cursor: AtomicUsize::new(0),
            swept: AtomicU64::new(0),
        }
    }

    #[inline(always)]
    fn lifeslot(&self, b: usize, slot: usize) -> usize {
        b * self.pairs.bucket_size + slot
    }

    /// Expire-on-read check for a located pair (see `DoubleHt`: colocated
    /// codes dedup against the tag probe, standalone touches its line).
    #[inline]
    fn is_expired(&self, b: usize, slot: usize) -> bool {
        match &self.life {
            Some(l) => {
                if let Some(meta) = &self.meta {
                    meta.touch_lifecycle(b, slot);
                }
                l.is_expired_at(self.lifeslot(b, slot))
            }
            None => false,
        }
    }

    /// Query-hit bookkeeping: bump frequency; `false` = expired (miss).
    #[inline]
    fn hit_live(&self, b: usize, slot: usize) -> bool {
        match &self.life {
            Some(l) => {
                if let Some(meta) = &self.meta {
                    meta.touch_lifecycle(b, slot);
                }
                l.on_hit(self.lifeslot(b, slot))
            }
            None => true,
        }
    }

    /// Stamp a just-published slot's lifecycle code (benign post-publish
    /// race with lock-free readers, as in `DoubleHt`).
    #[inline]
    fn stamp_fresh(&self, b: usize, slot: usize, ttl: Option<u64>) {
        if let Some(l) = &self.life {
            if let Some(meta) = &self.meta {
                meta.touch_lifecycle(b, slot);
            }
            l.fresh(self.lifeslot(b, slot), ttl);
        }
    }

    /// Reclaim an expired pair in place as a fresh insert of `val`.
    #[inline]
    fn reclaim_if_expired(&self, b: usize, slot: usize, val: u64, ttl: Option<u64>) -> bool {
        if !self.is_expired(b, slot) {
            return false;
        }
        self.pairs.value_store(b, slot, val);
        self.stamp_fresh(b, slot, ttl);
        true
    }

    #[inline(always)]
    fn overflowed(&self, b: usize) -> bool {
        self.overflow[b / 64].load(Ordering::Acquire) & (1 << (b % 64)) != 0
    }

    #[inline(always)]
    fn set_overflowed(&self, b: usize) {
        self.overflow[b / 64].fetch_or(1 << (b % 64), Ordering::AcqRel);
    }

    #[inline(always)]
    fn buckets_of(&self, key: u64) -> [usize; 2] {
        let mask = self.pairs.mask();
        [(hash1(key) & mask) as usize, (hash2(key) & mask) as usize]
    }

    /// Hoisted per-op tag (two fmix64 rounds — §Perf).
    #[inline(always)]
    fn tag_of(&self, key: u64) -> u16 {
        if self.meta.is_some() {
            tag16(key)
        } else {
            0
        }
    }

    fn view(&self, b: usize, key: u64, tag: u16, strong: bool) -> BucketView {
        if let Some(meta) = &self.meta {
            let ms = meta.scan(b, tag, strong);
            let found = self.pairs.scan_slots(b, ms.match_slots(), key, strong);
            BucketView {
                found,
                reusable: ms.reusable(),
                fill: ms.fill,
            }
        } else {
            let r = self.pairs.scan_bucket(b, key, strong);
            BucketView {
                found: r.found,
                reusable: r.reusable(),
                fill: r.fill,
            }
        }
    }

    fn apply_existing(&self, b: usize, slot: usize, old_v: u64, val: u64, op: &UpsertOp) {
        match op.merge(old_v, val) {
            Some(newv) => {
                if newv != old_v {
                    self.pairs.value_store(b, slot, newv);
                }
            }
            None => match op {
                UpsertOp::AddAssign => self.pairs.value_fetch_add(b, slot, val),
                UpsertOp::AddAssignF64 => {
                    self.pairs.value_fetch_add_f64(b, slot, f64::from_bits(val))
                }
                _ => unreachable!(),
            },
        }
    }

    /// Claim + publish into bucket `b`, returning the claimed slot;
    /// retries CAS races, `None` when the bucket fills up first.
    fn claim_in_bucket(&self, b: usize, key: u64, val: u64, tag: u16) -> Option<usize> {
        let strong = self.mode.strong();
        loop {
            let slot = if let Some(meta) = &self.meta {
                match meta.scan(b, tag, strong).reusable() {
                    Some(s) => s,
                    None => return None,
                }
            } else {
                match self.pairs.scan_bucket(b, key, strong).reusable() {
                    Some(s) => s,
                    None => return None,
                }
            };
            self.hook.on_event(RaceEvent::BeforeClaim { key, bucket: b });
            if let Some(meta) = &self.meta {
                if meta.try_claim(b, slot, tag, true) {
                    let ok = self.pairs.try_claim(b, slot, true);
                    debug_assert!(ok);
                    self.pairs.publish(b, slot, key, val);
                    return Some(slot);
                }
            } else if self.pairs.try_claim(b, slot, true) {
                self.pairs.publish(b, slot, key, val);
                return Some(slot);
            }
        }
    }

    /// Scalar upsert body; the caller holds b1's lock (in locking modes).
    /// Shared by the scalar API and the bulk path's fallback. `ttl`
    /// semantics as in `DoubleHt::upsert_under_lock`.
    fn upsert_under_lock(&self, key: u64, val: u64, op: &UpsertOp, ttl: Option<u64>) -> UpsertResult {
        let [b1, b2] = self.buckets_of(key);
        let tag = self.tag_of(key);
        let strong = self.mode.strong();
        let mut res = UpsertResult::Full;
        'done: {
            let v1 = self.view(b1, key, tag, strong);
            if let Some((slot, old_v)) = v1.found {
                if self.reclaim_if_expired(b1, slot, val, ttl) {
                    res = UpsertResult::Inserted;
                    break 'done;
                }
                self.apply_existing(b1, slot, old_v, val, op);
                if ttl.is_some() {
                    if let Some(l) = &self.life {
                        l.refresh(self.lifeslot(b1, slot), ttl);
                    }
                }
                res = UpsertResult::Updated;
                break 'done;
            }
            // Shortcut (paper §2.2): while the primary bucket's fill is
            // below 75% insert directly without loading the alternate
            // bucket. Sound only while b1's sticky overflow bit is clear
            // (no key of b1 can live in b2, so the duplicate check needs
            // only b1) and b1 still has a reusable slot.
            if v1.fill < self.shortcut_limit && !self.overflowed(b1) && v1.reusable.is_some() {
                if let Some(slot) = self.claim_in_bucket(b1, key, val, tag) {
                    self.stamp_fresh(b1, slot, ttl);
                    self.live.fetch_add(1, Ordering::Relaxed);
                    res = UpsertResult::Inserted;
                    break 'done;
                }
            }
            self.hook
                .on_event(RaceEvent::PrimaryFullMovingOn { key, bucket: b1 });
            let v2 = self.view(b2, key, tag, strong);
            if let Some((slot, old_v)) = v2.found {
                if self.reclaim_if_expired(b2, slot, val, ttl) {
                    res = UpsertResult::Inserted;
                    break 'done;
                }
                self.apply_existing(b2, slot, old_v, val, op);
                if ttl.is_some() {
                    if let Some(l) = &self.life {
                        l.refresh(self.lifeslot(b2, slot), ttl);
                    }
                }
                res = UpsertResult::Updated;
                break 'done;
            }
            // Power-of-two placement: less-loaded bucket first.
            let order = if v1.fill <= v2.fill { [b1, b2] } else { [b2, b1] };
            for b in order {
                if b == b2 {
                    // A key of b1 is (about to be) placed in its
                    // alternate: set the sticky bit BEFORE publishing so
                    // no shortcut can race past the duplicate check.
                    self.set_overflowed(b1);
                }
                if let Some(slot) = self.claim_in_bucket(b, key, val, tag) {
                    self.stamp_fresh(b, slot, ttl);
                    self.live.fetch_add(1, Ordering::Relaxed);
                    res = UpsertResult::Inserted;
                    break 'done;
                }
            }
        }
        res
    }

    /// Scalar erase body; caller holds b1's lock. Expired entries are
    /// physically reclaimed but reported absent.
    fn erase_under_lock(&self, key: u64) -> bool {
        let [b1, b2] = self.buckets_of(key);
        let strong = self.mode.strong();
        let tag = self.tag_of(key);
        let buckets: &[usize] = if self.overflowed(b1) { &[b1, b2] } else { &[b1] };
        for &b in buckets {
            if let Some((slot, _)) = self.view(b, key, tag, strong).found {
                let was_live = !self.is_expired(b, slot);
                self.kill_at(b, slot, key);
                return was_live;
            }
        }
        false
    }

    /// Tombstone a located pair (+ its tag + lifecycle code) and account
    /// the deletion.
    fn kill_at(&self, b: usize, slot: usize, key: u64) {
        self.pairs.kill(b, slot);
        if let Some(meta) = &self.meta {
            meta.kill(b, slot);
        }
        if let Some(l) = &self.life {
            l.clear(self.lifeslot(b, slot));
        }
        self.live.fetch_sub(1, Ordering::Relaxed);
        self.hook.on_event(RaceEvent::AfterDelete { key, bucket: b });
    }

    /// The sweep's guarded reclaim: kill `key` only if still expired,
    /// under b1's lock so it cannot race a refresh/reclaim.
    fn erase_expired(&self, key: u64) -> bool {
        let [b1, b2] = self.buckets_of(key);
        if self.mode.locking() {
            self.locks.lock(b1);
        }
        let strong = self.mode.strong();
        let tag = self.tag_of(key);
        let buckets: &[usize] = if self.overflowed(b1) { &[b1, b2] } else { &[b1] };
        let mut hit = false;
        for &b in buckets {
            if let Some((slot, _)) = self.view(b, key, tag, strong).found {
                if self.is_expired(b, slot) {
                    self.kill_at(b, slot, key);
                    hit = true;
                }
                break;
            }
        }
        if self.mode.locking() {
            self.locks.unlock(b1);
        }
        hit
    }

    /// Claim + publish from a group's shared free-slot list (shared
    /// protocol in [`super::common::claim_from_free`]); `None` when the
    /// scan-time list is exhausted (the caller re-walks scalar-style).
    fn claim_from(&self, b: usize, free: &mut FreeSlots, key: u64, val: u64) -> Option<usize> {
        super::common::claim_from_free(
            &self.pairs,
            self.meta.as_ref(),
            b,
            free,
            key,
            val,
            self.tag_of(key),
            self.hook.as_ref(),
        )
    }
}

impl ConcurrentMap for P2Ht {
    fn upsert(&self, key: u64, val: u64, op: &UpsertOp) -> UpsertResult {
        debug_assert!(crate::gpusim::mem::is_user_key(key));
        let b1 = self.buckets_of(key)[0];
        if self.mode.locking() {
            self.locks.lock(b1);
        }
        let res = self.upsert_under_lock(key, val, op, None);
        if self.mode.locking() {
            self.locks.unlock(b1);
        }
        res
    }

    fn upsert_ttl(&self, key: u64, val: u64, ttl_ticks: u64, op: &UpsertOp) -> UpsertResult {
        if self.life.is_none() {
            return self.upsert(key, val, op);
        }
        debug_assert!(crate::gpusim::mem::is_user_key(key));
        let b1 = self.buckets_of(key)[0];
        if self.mode.locking() {
            self.locks.lock(b1);
        }
        let res = self.upsert_under_lock(key, val, op, Some(ttl_ticks));
        if self.mode.locking() {
            self.locks.unlock(b1);
        }
        res
    }

    fn query(&self, key: u64) -> Option<u64> {
        let strong = self.mode.strong();
        let [b1, b2] = self.buckets_of(key);
        let tag = self.tag_of(key);
        if let Some((slot, v)) = self.view(b1, key, tag, strong).found {
            return self.hit_live(b1, slot).then_some(v);
        }
        if !self.overflowed(b1) {
            // No key of b1 has ever been placed in its alternate.
            return None;
        }
        self.view(b2, key, tag, strong)
            .found
            .and_then(|(slot, v)| self.hit_live(b2, slot).then_some(v))
    }

    fn erase(&self, key: u64) -> bool {
        let b1 = self.buckets_of(key)[0];
        if self.mode.locking() {
            self.locks.lock(b1);
        }
        let hit = self.erase_under_lock(key);
        if self.mode.locking() {
            self.locks.unlock(b1);
        }
        hit
    }

    fn upsert_bulk(&self, pairs_in: &[(u64, u64)], op: &UpsertOp, out: &mut Vec<UpsertResult>) {
        let base = out.len();
        out.resize(base + pairs_in.len(), UpsertResult::Full);
        let mut slots = super::SlotWriter::new(&mut out[base..]);
        let buckets: Vec<usize> =
            pairs_in.iter().map(|&(k, _)| self.buckets_of(k)[0]).collect();
        let locking = self.mode.locking();
        let strong = self.mode.strong();
        let mut tags: Vec<u16> = Vec::new();
        let mut per_tag: Vec<MetaScan> = Vec::new();
        let mut found: Vec<Option<(usize, u64)>> = Vec::new();
        let mut group_keys: Vec<u64> = Vec::new();
        super::for_each_bucket_group(&buckets, |b1, group| {
            if locking {
                self.locks.lock(b1);
            }
            if group.len() == 1 {
                let (k, v) = pairs_in[group[0] as usize];
                debug_assert!(crate::gpusim::mem::is_user_key(k));
                slots.set(group[0] as usize, self.upsert_under_lock(k, v, op, None));
            } else {
                // One shared scan of the group's common primary bucket.
                let (mut free, fill) = if let Some(meta) = &self.meta {
                    tags.clear();
                    tags.extend(group.iter().map(|&i| tag16(pairs_in[i as usize].0)));
                    meta.scan_group(b1, &tags, strong, &mut per_tag)
                } else {
                    group_keys.clear();
                    group_keys.extend(group.iter().map(|&i| pairs_in[i as usize].0));
                    self.pairs.scan_bucket_group(b1, &group_keys, strong, &mut found)
                };
                let mut local_fill = fill;
                let mut local: Vec<(u64, usize)> = Vec::new();
                let mut fallback_keys: Vec<u64> = Vec::new();
                for (j, &i) in group.iter().enumerate() {
                    let (k, v) = pairs_in[i as usize];
                    debug_assert!(crate::gpusim::mem::is_user_key(k));
                    if let Some(&(_, slot)) = local.iter().find(|&&(lk, _)| lk == k) {
                        let (_, old) = self.pairs.pair_at(b1, slot, strong);
                        self.apply_existing(b1, slot, old, v, op);
                        slots.set(i as usize, UpsertResult::Updated);
                        continue;
                    }
                    if fallback_keys.contains(&k) {
                        slots.set(i as usize, self.upsert_under_lock(k, v, op, None));
                        continue;
                    }
                    let hit = if self.meta.is_some() {
                        self.pairs.scan_slots(b1, per_tag[j].match_slots(), k, strong)
                    } else {
                        found[j]
                    };
                    if let Some((slot, _)) = hit {
                        if self.reclaim_if_expired(b1, slot, v, None) {
                            local.push((k, slot));
                            slots.set(i as usize, UpsertResult::Inserted);
                            continue;
                        }
                        // Fresh value read: the shared scan may predate
                        // merges applied earlier in this very group.
                        let (_, old) = self.pairs.pair_at(b1, slot, strong);
                        self.apply_existing(b1, slot, old, v, op);
                        slots.set(i as usize, UpsertResult::Updated);
                        continue;
                    }
                    // Shortcut fast path (§2.2), batch form: while b1's
                    // sticky overflow bit is clear no key of b1 can live
                    // in b2, so a miss in the shared b1 scan proves
                    // absence; insert into b1 without loading b2. The
                    // fill guard tracks this group's own inserts.
                    if !self.overflowed(b1) && local_fill < self.shortcut_limit {
                        if let Some(slot) = self.claim_from(b1, &mut free, k, v) {
                            self.stamp_fresh(b1, slot, None);
                            self.live.fetch_add(1, Ordering::Relaxed);
                            local_fill += 1;
                            local.push((k, slot));
                            slots.set(i as usize, UpsertResult::Inserted);
                            continue;
                        }
                    }
                    // Overflowed / crowded primary: full two-choice walk.
                    slots.set(i as usize, self.upsert_under_lock(k, v, op, None));
                    fallback_keys.push(k);
                }
            }
            if locking {
                self.locks.unlock(b1);
            }
        });
        slots.finish("P2HT::upsert_bulk");
    }

    fn query_bulk(&self, keys_in: &[u64], out: &mut Vec<Option<u64>>) {
        let base = out.len();
        out.resize(base + keys_in.len(), None);
        let mut slots = super::SlotWriter::new(&mut out[base..]);
        let buckets: Vec<usize> = keys_in.iter().map(|&k| self.buckets_of(k)[0]).collect();
        let strong = self.mode.strong();
        let mut tags: Vec<u16> = Vec::new();
        let mut per_tag: Vec<MetaScan> = Vec::new();
        let mut found: Vec<Option<(usize, u64)>> = Vec::new();
        let mut group_keys: Vec<u64> = Vec::new();
        super::for_each_bucket_group(&buckets, |b1, group| {
            if group.len() == 1 {
                let i = group[0] as usize;
                slots.set(i, self.query(keys_in[i]));
                return;
            }
            if let Some(meta) = &self.meta {
                tags.clear();
                tags.extend(group.iter().map(|&i| tag16(keys_in[i as usize])));
                meta.scan_group(b1, &tags, strong, &mut per_tag);
                for (j, &i) in group.iter().enumerate() {
                    let k = keys_in[i as usize];
                    slots.set(
                        i as usize,
                        match self.pairs.scan_slots(b1, per_tag[j].match_slots(), k, strong) {
                            // Expire-on-read, same as the scalar path.
                            Some((slot, v)) => self.hit_live(b1, slot).then_some(v),
                            // No key of b1 has ever overflowed into its
                            // alternate: a miss in b1 is a table miss.
                            None if !self.overflowed(b1) => None,
                            None => {
                                let b2 = self.buckets_of(k)[1];
                                self.view(b2, k, tags[j], strong)
                                    .found
                                    .and_then(|(slot, v)| self.hit_live(b2, slot).then_some(v))
                            }
                        },
                    );
                }
            } else {
                group_keys.clear();
                group_keys.extend(group.iter().map(|&i| keys_in[i as usize]));
                self.pairs.scan_bucket_group(b1, &group_keys, strong, &mut found);
                for (j, &i) in group.iter().enumerate() {
                    let k = keys_in[i as usize];
                    slots.set(
                        i as usize,
                        match found[j] {
                            Some((slot, v)) => self.hit_live(b1, slot).then_some(v),
                            None if !self.overflowed(b1) => None,
                            None => {
                                let b2 = self.buckets_of(k)[1];
                                self.view(b2, k, 0, strong)
                                    .found
                                    .and_then(|(slot, v)| self.hit_live(b2, slot).then_some(v))
                            }
                        },
                    );
                }
            }
        });
        slots.finish("P2HT::query_bulk");
    }

    fn erase_bulk(&self, keys_in: &[u64], out: &mut Vec<bool>) {
        let base = out.len();
        out.resize(base + keys_in.len(), false);
        let mut slots = super::SlotWriter::new(&mut out[base..]);
        let buckets: Vec<usize> = keys_in.iter().map(|&k| self.buckets_of(k)[0]).collect();
        let locking = self.mode.locking();
        let strong = self.mode.strong();
        let mut tags: Vec<u16> = Vec::new();
        let mut per_tag: Vec<MetaScan> = Vec::new();
        let mut found: Vec<Option<(usize, u64)>> = Vec::new();
        let mut group_keys: Vec<u64> = Vec::new();
        super::for_each_bucket_group(&buckets, |b1, group| {
            if locking {
                self.locks.lock(b1);
            }
            if group.len() == 1 {
                let i = group[0] as usize;
                slots.set(i, self.erase_under_lock(keys_in[i]));
            } else {
                if self.meta.is_some() {
                    tags.clear();
                    tags.extend(group.iter().map(|&i| tag16(keys_in[i as usize])));
                    self.meta
                        .as_ref()
                        .unwrap()
                        .scan_group(b1, &tags, strong, &mut per_tag);
                } else {
                    group_keys.clear();
                    group_keys.extend(group.iter().map(|&i| keys_in[i as usize]));
                    self.pairs.scan_bucket_group(b1, &group_keys, strong, &mut found);
                }
                let mut processed: Vec<u64> = Vec::new();
                for (j, &i) in group.iter().enumerate() {
                    let k = keys_in[i as usize];
                    if processed.contains(&k) {
                        slots.set(i as usize, self.erase_under_lock(k));
                        continue;
                    }
                    processed.push(k);
                    let hit = if self.meta.is_some() {
                        self.pairs.scan_slots(b1, per_tag[j].match_slots(), k, strong)
                    } else {
                        found[j]
                    };
                    slots.set(
                        i as usize,
                        match hit {
                            Some((slot, _)) => {
                                // Expired entries reclaim but report
                                // absent, same as the scalar path.
                                let was_live = !self.is_expired(b1, slot);
                                self.kill_at(b1, slot, k);
                                was_live
                            }
                            // Miss in b1 with the overflow bit clear: the
                            // key cannot be in b2, and under b1's lock it
                            // cannot appear concurrently.
                            None if !self.overflowed(b1) => false,
                            None => self.erase_under_lock(k),
                        },
                    );
                }
            }
            if locking {
                self.locks.unlock(b1);
            }
        });
        slots.finish("P2HT::erase_bulk");
    }

    fn num_buckets(&self) -> usize {
        self.pairs.num_buckets
    }

    fn primary_bucket(&self, key: u64) -> usize {
        self.buckets_of(key)[0]
    }

    fn capacity(&self) -> usize {
        self.pairs.num_buckets * self.pairs.bucket_size
    }

    fn len(&self) -> usize {
        self.live.load(Ordering::Relaxed) as usize
    }

    fn device_bytes(&self) -> usize {
        self.pairs.device_bytes()
            + self.meta.as_ref().map_or(0, |m| m.device_bytes())
            + self.life.as_ref().map_or(0, |l| l.device_bytes())
            + self.locks.bytes()
    }

    fn name(&self) -> &'static str {
        if self.meta.is_some() {
            "P2HT(M)"
        } else {
            "P2HT"
        }
    }

    fn is_stable(&self) -> bool {
        true
    }

    fn fetch_add_in_place(&self, key: u64, v: u64) -> bool {
        let strong = self.mode.strong();
        let tag = self.tag_of(key);
        for b in self.buckets_of(key) {
            if let Some((slot, _)) = self.view(b, key, tag, strong).found {
                if self.is_expired(b, slot) {
                    return false;
                }
                self.pairs.value_fetch_add(b, slot, v);
                return true;
            }
        }
        false
    }

    fn fetch_add_f64_in_place(&self, key: u64, v: f64) -> bool {
        let strong = self.mode.strong();
        let tag = self.tag_of(key);
        for b in self.buckets_of(key) {
            if let Some((slot, _)) = self.view(b, key, tag, strong).found {
                if self.is_expired(b, slot) {
                    return false;
                }
                self.pairs.value_fetch_add_f64(b, slot, v);
                return true;
            }
        }
        false
    }

    fn for_each_entry(&self, f: &mut dyn FnMut(u64, u64)) {
        // Expired entries are skipped — no resurrection through
        // migration/freeze collection.
        match &self.life {
            Some(l) => self.pairs.for_each_live_indexed(|b, s, k, v| {
                if !l.is_expired_at(b * self.pairs.bucket_size + s) {
                    f(k, v)
                }
            }),
            None => self.pairs.for_each_live(|k, v| f(k, v)),
        }
    }

    fn count_copies(&self, key: u64) -> usize {
        self.pairs.count_copies(key)
    }

    fn supports_ttl(&self) -> bool {
        self.life.is_some()
    }

    fn sweep_expired(&self, max_buckets: usize) -> usize {
        let Some(life) = &self.life else { return 0 };
        if max_buckets == 0 {
            return 0;
        }
        let nb = self.pairs.num_buckets;
        let start = self.sweep_cursor.fetch_add(max_buckets, Ordering::Relaxed) % nb;
        let mut victims: Vec<u64> = Vec::new();
        for i in 0..max_buckets.min(nb) {
            let b = (start + i) % nb;
            for s in 0..self.pairs.bucket_size {
                let k = self.pairs.key_at(b, s, false);
                if crate::gpusim::mem::is_user_key(k) && life.is_expired_at(self.lifeslot(b, s)) {
                    victims.push(k);
                }
            }
        }
        let mut reclaimed = 0;
        for k in victims {
            if self.erase_expired(k) {
                reclaimed += 1;
            }
        }
        self.swept.fetch_add(reclaimed as u64, Ordering::Relaxed);
        reclaimed
    }

    fn swept_expired(&self) -> u64 {
        self.swept.load(Ordering::Relaxed)
    }

    fn entry_frequency(&self, key: u64) -> Option<u8> {
        let life = self.life.as_ref()?;
        let strong = self.mode.strong();
        let tag = self.tag_of(key);
        for b in self.buckets_of(key) {
            if let Some((slot, _)) = self.view(b, key, tag, strong).found {
                if self.is_expired(b, slot) {
                    return None;
                }
                return Some(life.freq_at(self.lifeslot(b, slot)));
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tables::test_support::*;

    fn plain(slots: usize) -> P2Ht {
        P2Ht::new(TableConfig::new(slots).with_geometry(32, 8), false)
    }

    fn meta(slots: usize) -> P2Ht {
        P2Ht::new(TableConfig::new(slots).with_geometry(32, 4), true)
    }

    #[test]
    fn basic_crud() {
        check_basic_crud(&plain(2048));
        check_basic_crud(&meta(2048));
    }

    #[test]
    fn fills_to_90_percent() {
        check_fill_to(&plain(8192), 0.90);
        check_fill_to(&meta(8192), 0.90);
    }

    #[test]
    fn upsert_policies() {
        check_upsert_policies(&plain(2048));
        check_upsert_policies(&meta(2048));
    }

    #[test]
    fn aging_churn() {
        check_aging_churn(&plain(4096), 40);
        check_aging_churn(&meta(4096), 40);
    }

    #[test]
    fn concurrent_no_duplicates() {
        check_concurrent_no_duplicates(std::sync::Arc::new(plain(8192)));
        check_concurrent_no_duplicates(std::sync::Arc::new(meta(8192)));
    }

    #[test]
    fn concurrent_mixed() {
        check_concurrent_mixed(std::sync::Arc::new(plain(8192)));
    }

    #[test]
    fn in_place_accumulate() {
        check_fetch_add_in_place(&plain(2048));
        check_fetch_add_in_place(&meta(2048));
    }

    #[test]
    fn oracle_equivalence() {
        check_vs_oracle(&plain(4096), 0x21);
        check_vs_oracle(&meta(4096), 0x22);
    }

    #[test]
    fn shortcut_keeps_low_load_inserts_single_bucket() {
        // At low fill every key should land in its primary bucket.
        let t = plain(8192);
        let ks = keys(100, 0x5C);
        for &k in &ks {
            t.upsert(k, 1, &UpsertOp::InsertIfUnique);
        }
        for &k in &ks {
            let b1 = t.primary_bucket(k);
            let r = t.pairs.scan_bucket(b1, k, true);
            assert!(r.found.is_some(), "low-load key not in primary bucket");
        }
    }

    #[test]
    fn bsp_mode_fills() {
        let t = P2Ht::new(
            TableConfig::new(4096)
                .with_geometry(32, 8)
                .with_mode(ConcurrencyMode::Phased),
            false,
        );
        check_fill_to(&t, 0.85);
    }

    #[test]
    fn bulk_matches_scalar_twin() {
        check_bulk_parity(&plain(2048), &plain(2048), 0x23);
        check_bulk_parity(&meta(2048), &meta(2048), 0x24);
    }

    #[test]
    fn bulk_parity_with_overflowed_buckets() {
        // Tiny tables force alternate-bucket placement, exercising the
        // overflow-bit interplay with the grouped shortcut.
        check_bulk_parity(&plain(256), &plain(256), 0x25);
        check_bulk_parity(&meta(256), &meta(256), 0x26);
    }

    #[test]
    fn bulk_parity_without_shortcut() {
        let mk = || P2Ht::with_shortcut(TableConfig::new(1024).with_geometry(32, 8), false, false);
        check_bulk_parity(&mk(), &mk(), 0x27);
    }

    #[test]
    fn bulk_concurrent_no_duplicates() {
        check_bulk_concurrent_no_duplicates(std::sync::Arc::new(plain(8192)));
        check_bulk_concurrent_no_duplicates(std::sync::Arc::new(meta(8192)));
    }

    use crate::tables::lifecycle::LifecycleConfig;

    fn plain_ttl(slots: usize, cfg: &LifecycleConfig) -> P2Ht {
        P2Ht::new(
            TableConfig::new(slots)
                .with_geometry(32, 8)
                .with_lifecycle(cfg.clone()),
            false,
        )
    }

    fn meta_ttl(slots: usize, cfg: &LifecycleConfig) -> P2Ht {
        P2Ht::new(
            TableConfig::new(slots)
                .with_geometry(32, 4)
                .with_lifecycle(cfg.clone()),
            true,
        )
    }

    #[test]
    fn ttl_semantics_plain_and_meta() {
        let cfg = LifecycleConfig::new(3);
        check_ttl_semantics(&plain_ttl(2048, &cfg), &cfg);
        let cfg = LifecycleConfig::new(3);
        check_ttl_semantics(&meta_ttl(2048, &cfg), &cfg);
    }

    #[test]
    fn sweep_matches_expiry_oracle() {
        let cfg = LifecycleConfig::new(1);
        check_sweep_vs_oracle(&plain_ttl(2048, &cfg), &cfg);
        let cfg = LifecycleConfig::new(1);
        check_sweep_vs_oracle(&meta_ttl(2048, &cfg), &cfg);
    }

    #[test]
    fn bulk_ttl_parity_both_variants() {
        let cfg = LifecycleConfig::new(1);
        check_bulk_ttl_parity(&plain_ttl(2048, &cfg), &plain_ttl(2048, &cfg), &cfg, 0x28);
        let cfg = LifecycleConfig::new(1);
        check_bulk_ttl_parity(&meta_ttl(2048, &cfg), &meta_ttl(2048, &cfg), &cfg, 0x29);
    }

    #[test]
    fn meta_frequency_bumps_add_zero_probe_lines() {
        let cfg = LifecycleConfig::new(1);
        check_query_line_parity(&meta(4096), &meta_ttl(4096, &cfg), &cfg, 0x2A);
    }
}
