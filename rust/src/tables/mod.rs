//! The WarpSpeed hash-table library: eight concurrent designs plus the
//! baselines the paper compares against.
//!
//! | `TableKind`        | paper name     | design (paper §2.2, §5)                         |
//! |--------------------|----------------|--------------------------------------------------|
//! | `Double`           | DoubleHT       | double hashing, 8-slot buckets (1 line)          |
//! | `DoubleMeta`       | DoubleHT(M)    | + 16-bit fingerprint metadata, 32-slot buckets   |
//! | `P2`               | P2HT           | power-of-two-choice, 32-slot buckets, shortcut   |
//! | `P2Meta`           | P2HT(M)        | + metadata                                       |
//! | `Iceberg`          | IcebergHT      | front yard (83%, single hash) + backyard (p2)    |
//! | `IcebergMeta`      | IcebergHT(M)   | + metadata                                       |
//! | `Cuckoo`           | CuckooHT       | 3-way bucketed cuckoo, libcuckoo-style moves     |
//! | `Chaining`         | ChainingHT     | per-bucket linked lists, Gallatin-style slabs    |
//! | `SlabHashLike`     | SlabHash [3]   | lock-FREE upserts (INTENTIONALLY INCORRECT —     |
//! |                    |                | reproduces the §4.1 duplicate-key race)          |
//! | `WarpcoreLike`     | Warpcore [25]  | atomics-only, non-atomic pair writes, no         |
//! |                    |                | tombstone reuse (baseline, not concurrency-safe) |
//! | `BchtStatic`       | BCHT (BGHT)    | static bucketed cuckoo, BSP only                 |
//! | `P2bhtStatic`      | P2BHT (BGHT)   | static power-of-two, BSP only                    |
//!
//! All concurrent tables use one lock bit per bucket in an external
//! [`crate::gpusim::LockArray`], lock-free queries via the publish
//! protocol (the `.b128` vector-load analog), and support the paper's
//! upsert/query/erase API with compound upserts.

pub mod common;
pub mod meta;
pub mod lifecycle;
pub mod double;
pub mod p2;
pub mod iceberg;
pub mod cuckoo;
pub mod chaining;
pub mod frozen;
pub mod growable;
pub mod slabhash_like;
pub mod warpcore_like;
pub mod kernel_table;

pub use frozen::{FrozenTable, TieredMap};
pub use growable::{GrowableMap, GrowthPolicy};
pub use lifecycle::{LifecycleClock, LifecycleConfig};

#[cfg(test)]
pub(crate) mod test_support;

use std::sync::Arc;

use crate::gpusim::race::{NoopHook, RaceHook};

/// Concurrency discipline a table instance runs under.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConcurrencyMode {
    /// Fully concurrent: per-bucket locks for upserts/erases, morally
    /// strong (acquire/release) loads, publish-protocol pair writes.
    Concurrent,
    /// Bulk-synchronous phased mode: locks disabled, lazy (relaxed)
    /// cacheable loads — the paper's BSP comparison point (§6.2). Only
    /// correct when operations of different kinds never overlap.
    Phased,
}

impl ConcurrencyMode {
    #[inline(always)]
    pub fn strong(self) -> bool {
        matches!(self, ConcurrencyMode::Concurrent)
    }

    #[inline(always)]
    pub fn locking(self) -> bool {
        matches!(self, ConcurrencyMode::Concurrent)
    }
}

/// The compound-operation parameter of `Upsert` (paper §5.1). The paper
/// passes a device callback; here the policy is either one of the common
/// precompiled behaviours or an arbitrary closure.
pub enum UpsertOp<'a> {
    /// `f(){ return; }` — insert if absent, leave existing value alone.
    InsertIfUnique,
    /// Replace the existing value (plain "put").
    Overwrite,
    /// `atomicAdd(&loc->val, val)` — accumulate (u64 lanes).
    AddAssign,
    /// Accumulate interpreting the value slot as f64 bits (SpTC).
    AddAssignF64,
    /// Arbitrary merge: `new_value = f(existing_value, incoming_value)`.
    Custom(&'a (dyn Fn(u64, u64) -> u64 + Sync)),
}

impl<'a> UpsertOp<'a> {
    /// Merge an existing value with the incoming one per the policy.
    /// Returns `None` when the merge must be performed atomically in
    /// place (AddAssign*) rather than by store.
    #[inline]
    pub fn merge(&self, existing: u64, incoming: u64) -> Option<u64> {
        match self {
            UpsertOp::InsertIfUnique => Some(existing),
            UpsertOp::Overwrite => Some(incoming),
            UpsertOp::AddAssign => None,
            UpsertOp::AddAssignF64 => None,
            UpsertOp::Custom(f) => Some(f(existing, incoming)),
        }
    }
}

/// Outcome of an upsert.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UpsertResult {
    /// Key was absent and has been inserted.
    Inserted,
    /// Key was present and the policy was applied.
    Updated,
    /// Table (or the key's probe window) is full.
    Full,
}

/// The unified hash-table interface (paper §5.1) plus the introspection
/// hooks the adversarial benchmark requires (§4.1: "a CPU-side function
/// that returns the number of buckets and a GPU-side function that
/// returns the first bucket a key hashes to").
pub trait ConcurrentMap: Send + Sync {
    /// Upsert: insert `key → val` or combine with the existing value.
    fn upsert(&self, key: u64, val: u64, op: &UpsertOp) -> UpsertResult;

    /// Lock-free point query.
    fn query(&self, key: u64) -> Option<u64>;

    /// Remove a key. Returns true if it was present.
    fn erase(&self, key: u64) -> bool;

    /// Bulk upsert: apply the `(key, val)` pairs in slice order under one
    /// policy, appending one result per pair to `out`. Semantically
    /// identical to calling [`ConcurrentMap::upsert`] in a loop — in-batch
    /// per-key order is preserved, duplicate keys included. Native
    /// overrides group the batch by primary bucket (candidate-bucket
    /// triple for CuckooHT, chain bucket for ChainingHT) so one lock
    /// acquisition and one shared bucket scan or chain walk serve every
    /// op that hashes there (the warp-cooperative bulk-kernel analog).
    fn upsert_bulk(&self, pairs: &[(u64, u64)], op: &UpsertOp, out: &mut Vec<UpsertResult>) {
        out.reserve(pairs.len());
        for &(k, v) in pairs {
            out.push(self.upsert(k, v, op));
        }
    }

    /// Bulk lock-free point query: appends one result per key to `out`,
    /// in slice order.
    fn query_bulk(&self, keys: &[u64], out: &mut Vec<Option<u64>>) {
        out.reserve(keys.len());
        for &k in keys {
            out.push(self.query(k));
        }
    }

    /// Bulk erase: appends one result per key to `out`, preserving
    /// in-batch per-key order (duplicates: first hit erases, later ones
    /// report false).
    fn erase_bulk(&self, keys: &[u64], out: &mut Vec<bool>) {
        out.reserve(keys.len());
        for &k in keys {
            out.push(self.erase(k));
        }
    }

    /// Number of buckets (adversarial-benchmark extension).
    fn num_buckets(&self) -> usize;

    /// First bucket the key hashes to (adversarial-benchmark extension).
    fn primary_bucket(&self, key: u64) -> usize;

    /// Capacity in key-value pairs.
    fn capacity(&self) -> usize;

    /// Live keys (approximate under concurrency).
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total simulated device bytes (slots + metadata + locks + heads),
    /// for the space-efficiency benchmark (§6.1).
    fn device_bytes(&self) -> usize;

    /// Display name matching the paper's tables.
    fn name(&self) -> &'static str;

    /// Referential stability (paper §2.1). Stable tables never move a key
    /// after insertion, enabling lock-free fused read-modify-write.
    fn is_stable(&self) -> bool;

    /// In-place atomic accumulate without locks — only sound on stable
    /// tables (sparse tensor contraction fast path, §6.7). Returns false
    /// if the key is absent or the table is unstable.
    fn fetch_add_in_place(&self, key: u64, v: u64) -> bool {
        let _ = (key, v);
        false
    }

    /// f64-typed in-place accumulate (SpTC values).
    fn fetch_add_f64_in_place(&self, key: u64, v: f64) -> bool {
        let _ = (key, v);
        false
    }

    /// Count physical copies of `key` across every location the design
    /// could have stored it — the adversarial benchmark's correctness
    /// check. O(table) is fine; only used by tests/benches.
    fn count_copies(&self, key: u64) -> usize;

    /// Visit every live key-value pair (quiesced snapshot semantics: the
    /// caller must ensure no concurrent writers). Used for result export
    /// (sparse tensor contraction output) and BSP snapshotting.
    fn for_each_entry(&self, f: &mut dyn FnMut(u64, u64));

    /// Fraction of the nominal capacity currently occupied
    /// (`len / capacity`; approximate under concurrency, like `len`).
    /// The growth subsystem's trigger metric.
    fn load_factor(&self) -> f64 {
        let cap = self.capacity();
        if cap == 0 {
            0.0
        } else {
            self.len() as f64 / cap as f64
        }
    }

    /// True when the table can grow its capacity online
    /// ([`growable::GrowableMap`]). Plain designs are fixed-capacity and
    /// report `Full` when their probe windows saturate.
    fn can_grow(&self) -> bool {
        false
    }

    /// Ask the table to start (or join) a growth cycle. Returns true when
    /// a growth cycle is running or was just started; false for
    /// fixed-capacity tables (and for growable ones at their configured
    /// capacity ceiling).
    fn request_grow(&self) -> bool {
        false
    }

    /// True when the table can compact its capacity online
    /// ([`growable::GrowableMap`] with headroom above its initial
    /// provisioning). Plain designs are fixed-capacity.
    fn can_shrink(&self) -> bool {
        false
    }

    /// Ask the table to start a ½-capacity compaction cycle. Returns
    /// true when a shrink migration was just started; false for
    /// fixed-capacity tables, when a migration is already running, when
    /// the halved capacity would fall below the initial provisioning, or
    /// when current occupancy would put the successor above the grow
    /// watermark (see [`GrowthPolicy::shrink_below`]).
    fn request_shrink(&self) -> bool {
        false
    }

    /// Shrink events (½× successor allocations) over the table's
    /// lifetime; 0 for fixed-capacity designs.
    fn shrink_events(&self) -> u64 {
        0
    }

    /// True while an incremental old→successor migration is in progress.
    fn migration_in_progress(&self) -> bool {
        false
    }

    /// Advance an in-progress migration by up to `max_buckets` old-table
    /// buckets, returning the number of key-value pairs moved. No-op (0)
    /// for fixed-capacity tables or when no migration is running. Safe to
    /// call from any thread, concurrently with foreground operations —
    /// the coordinator's shard-affine workers drive this between batches.
    fn drive_migration(&self, max_buckets: usize) -> usize {
        let _ = max_buckets;
        0
    }

    /// Drive any in-progress migration to completion from the calling
    /// thread (quiesce helper for benches/tests/shutdown). Returns true
    /// when no migration remains; false when the migration is pinned at
    /// a capacity ceiling (successor full, growth refused) and cannot
    /// complete — operations stay correct either way, merely split
    /// across two tables. Fixed-capacity tables trivially return true.
    fn quiesce_migration(&self) -> bool {
        let mut stalls = 0;
        while self.migration_in_progress() {
            if self.drive_migration(usize::MAX) == 0 {
                stalls += 1;
                if stalls > 64 {
                    return false;
                }
            } else {
                stalls = 0;
            }
            std::thread::yield_now();
        }
        true
    }

    /// Migration iterator: append a snapshot of every live `(key, value)`
    /// whose PRIMARY bucket lies in `range` (buckets are indexed
    /// `0..num_buckets()`). Partitioning by *primary* bucket — not by
    /// storage slot — is what lets the growth subsystem serialize the
    /// migrator against foreground mutators with one lock per primary
    /// bucket, even on designs that displace keys into other buckets.
    /// The default is a full-table scan filtered by
    /// [`ConcurrentMap::primary_bucket`]; designs whose storage *is* the
    /// primary bucket (ChainingHT) override with a direct bucket walk.
    fn collect_primary_range(&self, range: std::ops::Range<usize>, out: &mut Vec<(u64, u64)>) {
        let mut f = |k: u64, v: u64| {
            if range.contains(&self.primary_bucket(k)) {
                out.push((k, v));
            }
        };
        self.for_each_entry(&mut f);
    }

    /// True when the table has a frozen read-optimized tier it can
    /// rebuild online ([`frozen::TieredMap`]). Plain designs have no
    /// frozen tier.
    fn can_freeze(&self) -> bool {
        false
    }

    /// Rebuild the frozen tier from every live entry (both tiers),
    /// leaving the mutable tier empty — quiesced-WRITER semantics like
    /// [`ConcurrentMap::for_each_entry`]; concurrent readers are safe.
    /// Returns the number of entries now frozen (0 for plain designs,
    /// and for tiered ones that are already fully frozen and dense).
    fn request_freeze(&self) -> usize {
        0
    }

    /// Live entries currently served by the frozen tier.
    fn frozen_len(&self) -> usize {
        0
    }

    /// Freeze cutovers over the table's lifetime.
    fn freeze_events(&self) -> u64 {
        0
    }

    /// True when this instance was built with entry-lifecycle metadata
    /// ([`TableConfig::with_lifecycle`]): TTL upserts are honored,
    /// queries expire-on-read, and lookups maintain per-entry frequency
    /// counters. Designs without lifecycle support (and instances built
    /// without it) report `false` and treat every entry as immortal.
    fn supports_ttl(&self) -> bool {
        false
    }

    /// Upsert with a time-to-live of `ttl_ticks` logical clock ticks
    /// ([`lifecycle::LifecycleClock`]). Semantics beyond
    /// [`ConcurrentMap::upsert`]:
    ///
    /// * a fresh insert stamps the entry's lifecycle code with the TTL
    ///   deadline (TTLs beyond the ring horizon are stored immortal —
    ///   an entry never expires *early*);
    /// * an update refreshes the existing entry's deadline in place,
    ///   preserving its frequency counter;
    /// * an upsert that lands on an *expired* entry of the same key
    ///   reclaims it as a fresh insert (value overwritten, lifecycle
    ///   reset, `Inserted` returned).
    ///
    /// The default ignores the TTL — non-lifecycle designs store the
    /// entry immortally, which is the conservative reading (data is
    /// never lost early).
    fn upsert_ttl(&self, key: u64, val: u64, ttl_ticks: u64, op: &UpsertOp) -> UpsertResult {
        let _ = ttl_ticks;
        self.upsert(key, val, op)
    }

    /// Advance the background expiry sweep by up to `max_buckets`
    /// buckets: physically reclaim entries whose TTL deadline has
    /// passed (queries already treat them as absent — expire-on-read —
    /// but the slots stay occupied until swept or overwritten). Returns
    /// the number of entries reclaimed. A per-instance cursor makes
    /// repeated bounded calls cover the whole table round-robin — the
    /// coordinator's `Job::Sweep` unit of work. No-op (0) without
    /// lifecycle support.
    fn sweep_expired(&self, max_buckets: usize) -> usize {
        let _ = max_buckets;
        0
    }

    /// Entries reclaimed by [`ConcurrentMap::sweep_expired`] over the
    /// table's lifetime (metrics).
    fn swept_expired(&self) -> u64 {
        0
    }

    /// Approximate access-frequency counter of `key`'s entry (0..=7,
    /// bumped saturating on every successful lookup), or `None` when
    /// the key is absent, expired, or the instance has no lifecycle
    /// metadata. Reads without bumping — usable as a residency probe
    /// and as the eviction-policy input
    /// ([`crate::apps::caching::GpuCache`]).
    fn entry_frequency(&self, key: u64) -> Option<u8> {
        let _ = key;
        None
    }

    /// Routing-stripe migration iterator (shard split/merge): append a
    /// snapshot of every live `(key, value)` whose key satisfies `keep`
    /// — a pure routing predicate (stripe-range membership plus, for
    /// splits, the mover bit), supplied by the sharded table. Unlike
    /// [`ConcurrentMap::collect_primary_range`], routing stripes are
    /// hash-scattered across buckets, so every design visits its whole
    /// storage; the default pays two virtual dispatches per entry
    /// (through `for_each_entry` and the predicate's closure chain),
    /// and designs with directly walkable storage (ChainingHT) override
    /// with a raw walk that applies the predicate inline — that per-claim
    /// constant is what split/merge stripe claims pay on every scan.
    fn collect_stripe_range(&self, keep: &dyn Fn(u64) -> bool, out: &mut Vec<(u64, u64)>) {
        self.for_each_entry(&mut |k, v| {
            if keep(k) {
                out.push((k, v));
            }
        });
    }
}

/// Identifies a table design for the factory + benchmark harness.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TableKind {
    Double,
    DoubleMeta,
    P2,
    P2Meta,
    Iceberg,
    IcebergMeta,
    Cuckoo,
    Chaining,
    /// Linear-probing baseline (§2.2 design space; not one of the eight).
    Linear,
    SlabHashLike,
    WarpcoreLike,
    BchtStatic,
    P2bhtStatic,
}

impl TableKind {
    /// The eight designs evaluated as fully concurrent tables (§5).
    pub const CONCURRENT: [TableKind; 8] = [
        TableKind::Double,
        TableKind::DoubleMeta,
        TableKind::Iceberg,
        TableKind::IcebergMeta,
        TableKind::P2,
        TableKind::P2Meta,
        TableKind::Cuckoo,
        TableKind::Chaining,
    ];

    /// Every variant the factory can build (round-trip + factory tests).
    pub const ALL: [TableKind; 13] = [
        TableKind::Double,
        TableKind::DoubleMeta,
        TableKind::P2,
        TableKind::P2Meta,
        TableKind::Iceberg,
        TableKind::IcebergMeta,
        TableKind::Cuckoo,
        TableKind::Chaining,
        TableKind::Linear,
        TableKind::SlabHashLike,
        TableKind::WarpcoreLike,
        TableKind::BchtStatic,
        TableKind::P2bhtStatic,
    ];

    pub fn paper_name(&self) -> &'static str {
        match self {
            TableKind::Double => "DoubleHT",
            TableKind::DoubleMeta => "DoubleHT(M)",
            TableKind::P2 => "P2HT",
            TableKind::P2Meta => "P2HT(M)",
            TableKind::Iceberg => "IcebergHT",
            TableKind::IcebergMeta => "IcebergHT(M)",
            TableKind::Cuckoo => "CuckooHT",
            TableKind::Chaining => "ChainingHT",
            TableKind::Linear => "LinearHT",
            TableKind::SlabHashLike => "SlabHash-like",
            TableKind::WarpcoreLike => "Warpcore-like",
            TableKind::BchtStatic => "BCHT(BGHT)",
            TableKind::P2bhtStatic => "P2BHT(BGHT)",
        }
    }

    pub fn from_name(s: &str) -> Option<TableKind> {
        let t = match s.to_ascii_lowercase().as_str() {
            "double" | "doubleht" => TableKind::Double,
            "double_meta" | "doubleht(m)" | "doublem" => TableKind::DoubleMeta,
            "p2" | "p2ht" => TableKind::P2,
            "p2_meta" | "p2ht(m)" | "p2m" => TableKind::P2Meta,
            "iceberg" | "iceberght" => TableKind::Iceberg,
            "iceberg_meta" | "iceberght(m)" | "icebergm" => TableKind::IcebergMeta,
            "cuckoo" | "cuckooht" => TableKind::Cuckoo,
            "chaining" | "chaininght" => TableKind::Chaining,
            "linear" | "linearht" => TableKind::Linear,
            "slabhash" | "slabhash_like" | "slabhash-like" => TableKind::SlabHashLike,
            "warpcore" | "warpcore_like" | "warpcore-like" => TableKind::WarpcoreLike,
            "bcht" | "bcht(bght)" => TableKind::BchtStatic,
            "p2bht" | "p2bht(bght)" => TableKind::P2bhtStatic,
            _ => return None,
        };
        Some(t)
    }

    /// Paper §5 per-design default (bucket_size, tile_size).
    pub fn default_geometry(&self) -> (usize, usize) {
        match self {
            TableKind::Double => (8, 8),
            TableKind::DoubleMeta => (32, 4),
            TableKind::P2 => (32, 8),
            TableKind::P2Meta => (32, 4),
            TableKind::Iceberg => (32, 8),
            TableKind::IcebergMeta => (32, 4),
            TableKind::Cuckoo => (8, 4),
            TableKind::Chaining => (7, 4),
            TableKind::Linear => (8, 8),
            TableKind::SlabHashLike => (8, 4),
            TableKind::WarpcoreLike => (8, 8),
            TableKind::BchtStatic => (8, 32),
            TableKind::P2bhtStatic => (32, 32),
        }
    }
}

/// Construction parameters for any table design.
#[derive(Clone)]
pub struct TableConfig {
    /// Requested capacity in key-value slots; rounded up so the bucket
    /// count is a power of two.
    pub slots: usize,
    /// Key-value pairs per bucket (paper's templated bucket size).
    pub bucket_size: usize,
    /// Threads per cooperative tile (affects the cost model + reported
    /// geometry; the functional scan order is tile-chunked).
    pub tile_size: usize,
    pub mode: ConcurrencyMode,
    /// Max buckets probed before an open-addressing op gives up.
    pub max_probes: usize,
    /// Adversarial-schedule hook (Noop in production).
    pub hook: Arc<dyn RaceHook>,
    /// Entry-lifecycle metadata (TTL + frequency counters). `None`
    /// (the default) builds the table without lifecycle slots: zero
    /// memory overhead, every entry immortal, `upsert_ttl` degrades to
    /// plain `upsert`. Cloned configs (growth successors) share the
    /// same logical clock through the embedded `Arc`.
    pub lifecycle: Option<LifecycleConfig>,
}

impl TableConfig {
    pub fn new(slots: usize) -> Self {
        Self {
            slots,
            bucket_size: 8,
            tile_size: 8,
            mode: ConcurrencyMode::Concurrent,
            max_probes: 128,
            hook: Arc::new(NoopHook),
            lifecycle: None,
        }
    }

    pub fn for_kind(kind: TableKind, slots: usize) -> Self {
        let (b, t) = kind.default_geometry();
        let mut c = Self::new(slots);
        c.bucket_size = b;
        c.tile_size = t;
        if matches!(kind, TableKind::BchtStatic | TableKind::P2bhtStatic) {
            c.mode = ConcurrencyMode::Phased;
        }
        if matches!(kind, TableKind::Double | TableKind::DoubleMeta) {
            // The paper's double-hashing probe window: aged negative
            // queries cost up to ~80 probes (Table 5.1).
            c.max_probes = 80;
        }
        c
    }

    pub fn with_mode(mut self, mode: ConcurrencyMode) -> Self {
        self.mode = mode;
        self
    }

    pub fn with_geometry(mut self, bucket_size: usize, tile_size: usize) -> Self {
        self.bucket_size = bucket_size;
        self.tile_size = tile_size;
        self
    }

    pub fn with_hook(mut self, hook: Arc<dyn RaceHook>) -> Self {
        self.hook = hook;
        self
    }

    pub fn with_lifecycle(mut self, cfg: LifecycleConfig) -> Self {
        self.lifecycle = Some(cfg);
        self
    }
}

/// Stable grouping of a batch by bucket, shared by every native bulk
/// implementation: sorts the indices `0..buckets.len()` by
/// `(bucket, arrival index)` and invokes `f(bucket, indices)` once per
/// distinct bucket. Arrival order is preserved within each group, which
/// is what keeps in-batch per-key operation order intact (same key ⇒
/// same primary bucket ⇒ same group).
pub(crate) fn for_each_bucket_group(buckets: &[usize], mut f: impl FnMut(usize, &[u32])) {
    let n = buckets.len();
    debug_assert!(n <= u32::MAX as usize, "batch too large for u32 indices");
    let mut order: Vec<u32> = (0..n as u32).collect();
    order.sort_unstable_by_key(|&i| (buckets[i as usize], i));
    let mut g = 0usize;
    while g < n {
        let b = buckets[order[g] as usize];
        let mut e = g + 1;
        while e < n && buckets[order[e] as usize] == b {
            e += 1;
        }
        crate::gpusim::probes::count_bulk_group();
        f(b, &order[g..e]);
        g = e;
    }
}

/// [`for_each_bucket_group`] generalized to CuckooHT's candidate-bucket
/// triples: ops whose keys share all three candidate buckets (duplicate
/// keys in a batch, chiefly) form one group, so `lock_three` is taken
/// once per group instead of once per op. Grouping is by the *ordered*
/// triple — group members scan and claim buckets in the identical
/// preference order the scalar path uses — and arrival order is
/// preserved within each group (same key ⇒ same triple ⇒ same group).
pub(crate) fn for_each_triple_group(triples: &[[usize; 3]], mut f: impl FnMut([usize; 3], &[u32])) {
    let n = triples.len();
    debug_assert!(n <= u32::MAX as usize, "batch too large for u32 indices");
    let mut order: Vec<u32> = (0..n as u32).collect();
    order.sort_unstable_by_key(|&i| (triples[i as usize], i));
    let mut g = 0usize;
    while g < n {
        let t = triples[order[g] as usize];
        let mut e = g + 1;
        while e < n && triples[order[e] as usize] == t {
            e += 1;
        }
        crate::gpusim::probes::count_bulk_group();
        f(t, &order[g..e]);
        g = e;
    }
}

/// Debug-checked writer over the output slots one native bulk call owns.
///
/// Native bulk paths pre-fill their output region with a sentinel
/// (`UpsertResult::Full` / `None` / `false`) and rely on every grouped op
/// overwriting its slot. The sentinels double as legitimate results, so a
/// skipped index would silently read as a real Full/miss instead of
/// failing loudly. In debug builds this wrapper records every `set` and
/// `finish` panics naming the first slot the group loops never wrote; in
/// release builds it compiles down to the raw slice store.
pub(crate) struct SlotWriter<'a, T> {
    out: &'a mut [T],
    #[cfg(debug_assertions)]
    written: Vec<bool>,
}

impl<'a, T> SlotWriter<'a, T> {
    pub(crate) fn new(out: &'a mut [T]) -> Self {
        #[cfg(debug_assertions)]
        let written = vec![false; out.len()];
        Self {
            out,
            #[cfg(debug_assertions)]
            written,
        }
    }

    #[inline(always)]
    pub(crate) fn set(&mut self, i: usize, v: T) {
        self.out[i] = v;
        #[cfg(debug_assertions)]
        {
            self.written[i] = true;
        }
    }

    /// Assert every slot was written (debug builds only).
    pub(crate) fn finish(self, _what: &str) {
        #[cfg(debug_assertions)]
        if let Some(miss) = self.written.iter().position(|w| !w) {
            panic!("native bulk path `{_what}` skipped output slot {miss}");
        }
    }
}

/// Build a table of the given design with its paper-default geometry.
pub fn build_table(kind: TableKind, slots: usize) -> Arc<dyn ConcurrentMap> {
    build_table_with(kind, TableConfig::for_kind(kind, slots))
}

/// Build a table of the given design with an explicit configuration.
pub fn build_table_with(kind: TableKind, cfg: TableConfig) -> Arc<dyn ConcurrentMap> {
    match kind {
        TableKind::Double => Arc::new(double::DoubleHt::new(cfg, false)),
        TableKind::DoubleMeta => Arc::new(double::DoubleHt::new(cfg, true)),
        TableKind::P2 => Arc::new(p2::P2Ht::new(cfg, false)),
        TableKind::P2Meta => Arc::new(p2::P2Ht::new(cfg, true)),
        TableKind::Iceberg => Arc::new(iceberg::IcebergHt::new(cfg, false)),
        TableKind::IcebergMeta => Arc::new(iceberg::IcebergHt::new(cfg, true)),
        TableKind::Cuckoo => Arc::new(cuckoo::CuckooHt::new(cfg)),
        TableKind::Chaining => Arc::new(chaining::ChainingHt::new(cfg)),
        TableKind::Linear => Arc::new(double::DoubleHt::with_strategy(cfg, false, true)),
        TableKind::SlabHashLike => Arc::new(slabhash_like::SlabHashLike::new(cfg)),
        TableKind::WarpcoreLike => Arc::new(warpcore_like::WarpcoreLike::new(cfg)),
        TableKind::BchtStatic => Arc::new(cuckoo::CuckooHt::new(
            cfg.with_mode(ConcurrencyMode::Phased),
        )),
        TableKind::P2bhtStatic => {
            Arc::new(p2::P2Ht::new(cfg.with_mode(ConcurrencyMode::Phased), false))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_roundtrip_names() {
        // Every variant's paper name must parse back to the same kind —
        // the CLI accepts paper names, so any asymmetry here makes a
        // design unreachable from the command line.
        for k in TableKind::ALL {
            let n = k.paper_name();
            assert_eq!(TableKind::from_name(n), Some(k), "{n}");
        }
    }

    #[test]
    fn all_list_is_exhaustive_and_distinct() {
        let mut seen = std::collections::HashSet::new();
        for k in TableKind::ALL {
            assert!(seen.insert(k), "{k:?} listed twice");
        }
        for k in TableKind::CONCURRENT {
            assert!(seen.contains(&k), "{k:?} missing from ALL");
        }
    }

    #[test]
    fn default_geometry_matches_paper_section5() {
        assert_eq!(TableKind::Double.default_geometry(), (8, 8));
        assert_eq!(TableKind::DoubleMeta.default_geometry(), (32, 4));
        assert_eq!(TableKind::Iceberg.default_geometry(), (32, 8));
        assert_eq!(TableKind::Cuckoo.default_geometry(), (8, 4));
        assert_eq!(TableKind::Chaining.default_geometry(), (7, 4));
    }

    #[test]
    fn merge_policies() {
        assert_eq!(UpsertOp::InsertIfUnique.merge(5, 9), Some(5));
        assert_eq!(UpsertOp::Overwrite.merge(5, 9), Some(9));
        assert_eq!(UpsertOp::AddAssign.merge(5, 9), None);
        let f = |a: u64, b: u64| a.max(b);
        assert_eq!(UpsertOp::Custom(&f).merge(5, 9), Some(9));
    }

    #[test]
    fn factory_builds_all_kinds() {
        for k in TableKind::ALL {
            let t = build_table(k, 4096);
            assert!(t.capacity() >= 1024, "{:?} too small", k);
            assert!(t.num_buckets() > 0);
            assert_eq!(t.len(), 0);
        }
    }
}
