//! Entry-lifecycle metadata: a logical TTL clock plus one 8-bit
//! lifecycle code per slot (segcache-style; pelikan packs a comparable
//! 12-bit tag + 8-bit frequency per item).
//!
//! # Clock model
//!
//! Wall time is useless inside the deterministic gpusim testbed, so the
//! clock is a shared `AtomicU64` of *logical ticks* advanced explicitly
//! by the workload driver ([`LifecycleClock::advance`]). TTLs are
//! expressed in ticks and quantized: [`LifecycleConfig::quantum`] ticks
//! form one TTL quantum, and a code stores its expiry deadline as a
//! quantum index modulo 16 (a sequence-number ring, compared with a
//! half-window test like TCP sequence arithmetic).
//!
//! # Code layout (8 bits per slot)
//!
//! ```text
//!   bit 7      : has_ttl (0 = immortal)
//!   bits 6..4  : saturating frequency counter, 0..=7
//!   bits 3..0  : expiry deadline, in quanta mod 16 (TTL entries only)
//! ```
//!
//! `0x00` — immortal, never touched — is the natural zero-initialized
//! state, so tables without TTL traffic pay nothing. The 4-bit ring
//! bounds representable TTLs at [`TTL_HORIZON_QUANTA`] quanta: longer
//! TTLs round *up* to immortal (an entry never expires early). An
//! expired entry reads as expired for the 9 quanta after its deadline;
//! a sweep (or any write that reclaims the slot) must run within that
//! window or the ring wraps and the corpse transiently reads live again
//! — the background sweep cadence is what bounds this, exactly like
//! segcache's eager segment expiry.
//!
//! # Line accounting
//!
//! Frequency bumps must not add cache-line probes to the query hot path
//! (the paper's one-line-metadata argument). Two storage modes:
//!
//! * **Colocated** ([`LifecycleSlots::colocated`]): the codes live in
//!   spare bytes of a line the operation already touched — the padded
//!   tail of a [`super::meta::MetaArray`] bucket region, or ChainingHT's
//!   free pad word inside each 128-byte node. Accounting is carried by
//!   the host structure's own touch; reads/bumps here add zero lines.
//! * **Standalone** ([`LifecycleSlots::standalone`]): designs with no
//!   spare metadata bytes (plain Double/P2, Cuckoo, the baselines) keep
//!   codes in their own array and honestly touch its lines.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::gpusim::probes;

/// Deterministic logical clock shared by every table built from one
/// [`LifecycleConfig`] (clone the config → share the clock).
#[derive(Debug, Default)]
pub struct LifecycleClock {
    ticks: AtomicU64,
}

impl LifecycleClock {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    #[inline]
    pub fn now(&self) -> u64 {
        self.ticks.load(Ordering::Relaxed)
    }

    /// Advance the clock by `n` ticks, returning the new now.
    #[inline]
    pub fn advance(&self, n: u64) -> u64 {
        self.ticks.fetch_add(n, Ordering::Relaxed) + n
    }
}

/// Lifecycle wiring for one table: the shared clock plus the tick→
/// quantum coarsening. Cloning shares the clock (the point: a sharded
/// table's shards must agree on "now").
#[derive(Clone, Debug)]
pub struct LifecycleConfig {
    pub clock: Arc<LifecycleClock>,
    /// Ticks per TTL quantum (≥ 1). Coarser quanta stretch the 7-quantum
    /// TTL horizon at the price of coarser expiry.
    pub quantum: u64,
}

impl LifecycleConfig {
    pub fn new(quantum: u64) -> Self {
        Self {
            clock: LifecycleClock::new(),
            quantum: quantum.max(1),
        }
    }

    #[inline]
    pub fn now_quantum(&self) -> u64 {
        self.clock.now() / self.quantum
    }

    /// TTL in ticks → quanta, rounded up so an entry never expires
    /// before its requested TTL; `None` = beyond the ring horizon
    /// (stored immortal).
    #[inline]
    pub fn ttl_quanta(&self, ttl_ticks: u64) -> Option<u64> {
        let q = ttl_ticks.div_ceil(self.quantum).max(1);
        (q <= TTL_HORIZON_QUANTA).then_some(q)
    }
}

/// Longest representable TTL, in quanta (the live half of the mod-16
/// deadline ring minus the current quantum).
pub const TTL_HORIZON_QUANTA: u64 = 7;

/// Frequency-counter ceiling (3 bits, saturating).
pub const FREQ_MAX: u8 = 7;

const TTL_BIT: u8 = 0x80;
const FREQ_MASK: u8 = 0x70;
const FREQ_SHIFT: u32 = 4;
const DEADLINE_MASK: u8 = 0x0F;

/// Code for a freshly (re)inserted entry: frequency 0, deadline
/// `now + ttl_quanta` when a TTL within the horizon was requested.
#[inline]
pub fn encode_fresh(now_quantum: u64, ttl_quanta: Option<u64>) -> u8 {
    match ttl_quanta {
        Some(q) => TTL_BIT | ((now_quantum.wrapping_add(q) & 0xF) as u8),
        None => 0,
    }
}

/// Half-window ring comparison: expired iff the entry carries a TTL and
/// `now` sits in the 9-quantum window at/after its deadline.
#[inline]
pub fn is_expired(code: u8, now_quantum: u64) -> bool {
    code & TTL_BIT != 0 && (now_quantum.wrapping_sub((code & DEADLINE_MASK) as u64) & 0xF) <= 8
}

#[inline]
pub fn freq_of(code: u8) -> u8 {
    (code & FREQ_MASK) >> FREQ_SHIFT
}

/// Saturating frequency bump, deadline and TTL bit preserved.
#[inline]
pub fn bumped(code: u8) -> u8 {
    if freq_of(code) >= FREQ_MAX {
        code
    } else {
        code + (1 << FREQ_SHIFT)
    }
}

static NEXT_LIFE_MEM_ID: AtomicU64 = AtomicU64::new(1);

/// Per-slot lifecycle codes for one table region, packed 8 per
/// `AtomicU64`. Slot indexing is the owner's flat slot index
/// (`bucket * bucket_size + slot` for the open-addressing designs).
pub struct LifecycleSlots {
    cfg: LifecycleConfig,
    words: Box<[AtomicU64]>,
    n_slots: usize,
    /// `None` = colocated (lines carried by the host structure's touch);
    /// `Some(mem_id)` = standalone array with its own device lines.
    mem_id: Option<u64>,
}

impl LifecycleSlots {
    /// Codes riding spare bytes of lines the owner already touches
    /// (MetaArray bucket-region tail, ChainingHT node pad word). Zero
    /// extra lines on any path — the owner's layout reserves the bytes
    /// and its own `touch` covers them.
    pub fn colocated(cfg: LifecycleConfig, n_slots: usize) -> Self {
        Self::build(cfg, n_slots, None)
    }

    /// Codes in their own array with honest line accounting (1 byte per
    /// slot, 128 codes per line).
    pub fn standalone(cfg: LifecycleConfig, n_slots: usize) -> Self {
        Self::build(
            cfg,
            n_slots,
            Some(NEXT_LIFE_MEM_ID.fetch_add(1, Ordering::Relaxed)),
        )
    }

    fn build(cfg: LifecycleConfig, n_slots: usize, mem_id: Option<u64>) -> Self {
        let nw = n_slots.div_ceil(8).max(1);
        let mut v = Vec::with_capacity(nw);
        v.resize_with(nw, || AtomicU64::new(0));
        Self {
            cfg,
            words: v.into_boxed_slice(),
            n_slots,
            mem_id,
        }
    }

    #[inline]
    pub fn cfg(&self) -> &LifecycleConfig {
        &self.cfg
    }

    /// Device bytes this region adds (0 when colocated: the owner's
    /// layout already reserves — and reports — the bytes).
    pub fn device_bytes(&self) -> usize {
        match self.mem_id {
            Some(_) => self.n_slots,
            None => 0,
        }
    }

    #[inline]
    fn touch(&self, slot: usize) {
        if let Some(id) = self.mem_id {
            if probes::enabled() {
                let line = (slot / crate::gpusim::LINE_BYTES) as u64;
                probes::touch((0x4000_0000_0000 | id) << 16 | line);
            }
        }
    }

    #[inline]
    fn cell(&self, slot: usize) -> (&AtomicU64, u32) {
        debug_assert!(slot < self.n_slots, "lifecycle slot {slot} out of range");
        (&self.words[slot / 8], (slot % 8) as u32 * 8)
    }

    #[inline]
    pub fn code(&self, slot: usize) -> u8 {
        self.touch(slot);
        let (w, sh) = self.cell(slot);
        (w.load(Ordering::Acquire) >> sh) as u8
    }

    #[inline]
    pub fn set(&self, slot: usize, code: u8) {
        self.touch(slot);
        let (w, sh) = self.cell(slot);
        let mask = 0xFFu64 << sh;
        let mut cur = w.load(Ordering::Acquire);
        loop {
            let new = (cur & !mask) | ((code as u64) << sh);
            match w.compare_exchange_weak(cur, new, Ordering::AcqRel, Ordering::Acquire) {
                Ok(_) => return,
                Err(c) => cur = c,
            }
        }
    }

    #[inline]
    pub fn clear(&self, slot: usize) {
        self.set(slot, 0);
    }

    /// Stamp a freshly claimed (or reclaimed) slot: frequency 0 plus the
    /// requested TTL deadline.
    #[inline]
    pub fn fresh(&self, slot: usize, ttl_ticks: Option<u64>) {
        let q = ttl_ticks.and_then(|t| self.cfg.ttl_quanta(t));
        self.set(slot, encode_fresh(self.cfg.now_quantum(), q));
    }

    #[inline]
    pub fn is_expired_at(&self, slot: usize) -> bool {
        is_expired(self.code(slot), self.cfg.now_quantum())
    }

    #[inline]
    pub fn freq_at(&self, slot: usize) -> u8 {
        freq_of(self.code(slot))
    }

    /// Query-hit hook: `false` when the entry is expired (the caller
    /// reports a miss); otherwise bumps the saturating frequency counter
    /// in place and returns `true`. One CAS on the same word the code
    /// read loaded — no additional line in either storage mode.
    #[inline]
    pub fn on_hit(&self, slot: usize) -> bool {
        self.touch(slot);
        let (w, sh) = self.cell(slot);
        let now_q = self.cfg.now_quantum();
        let mask = 0xFFu64 << sh;
        let mut cur = w.load(Ordering::Acquire);
        loop {
            let code = (cur >> sh) as u8;
            if is_expired(code, now_q) {
                return false;
            }
            let b = bumped(code);
            if b == code {
                return true; // saturated: no write needed
            }
            let new = (cur & !mask) | ((b as u64) << sh);
            match w.compare_exchange_weak(cur, new, Ordering::AcqRel, Ordering::Acquire) {
                Ok(_) => return true,
                Err(c) => cur = c,
            }
        }
    }

    /// Refresh a live entry's TTL in place (upsert-with-TTL on an
    /// existing key), preserving its frequency.
    #[inline]
    pub fn refresh(&self, slot: usize, ttl_ticks: Option<u64>) {
        let q = ttl_ticks.and_then(|t| self.cfg.ttl_quanta(t));
        let freq_bits = self.code(slot) & FREQ_MASK;
        self.set(slot, encode_fresh(self.cfg.now_quantum(), q) | freq_bits);
    }

    /// Move a code with its entry (CuckooHT displacement under lock).
    #[inline]
    pub fn move_code(&self, from: usize, to: usize) {
        let c = self.code(from);
        self.set(to, c);
        self.clear(from);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_advances_monotonically() {
        let c = LifecycleClock::new();
        assert_eq!(c.now(), 0);
        assert_eq!(c.advance(5), 5);
        assert_eq!(c.advance(3), 8);
        assert_eq!(c.now(), 8);
    }

    #[test]
    fn ttl_quantization_rounds_up_and_caps_at_horizon() {
        let cfg = LifecycleConfig::new(10);
        assert_eq!(cfg.ttl_quanta(1), Some(1));
        assert_eq!(cfg.ttl_quanta(10), Some(1));
        assert_eq!(cfg.ttl_quanta(11), Some(2));
        assert_eq!(cfg.ttl_quanta(70), Some(7));
        assert_eq!(cfg.ttl_quanta(71), None, "beyond horizon → immortal");
    }

    #[test]
    fn ring_expiry_half_window() {
        for start in [0u64, 7, 13, 100, u64::MAX - 3] {
            for ttl in 1..=TTL_HORIZON_QUANTA {
                let code = encode_fresh(start, Some(ttl));
                for dt in 0..ttl {
                    assert!(
                        !is_expired(code, start.wrapping_add(dt)),
                        "start {start} ttl {ttl} dt {dt}"
                    );
                }
                for dt in ttl..ttl + 9 {
                    assert!(is_expired(code, start.wrapping_add(dt)), "start {start} ttl {ttl} dt {dt}");
                }
            }
        }
    }

    #[test]
    fn immortal_never_expires() {
        let code = encode_fresh(3, None);
        for q in 0..64u64 {
            assert!(!is_expired(code, q));
        }
        // Frequency bumps never turn an immortal entry mortal.
        let mut c = code;
        for _ in 0..20 {
            c = bumped(c);
        }
        assert!(!is_expired(c, 11));
        assert_eq!(freq_of(c), FREQ_MAX);
    }

    #[test]
    fn bump_saturates_and_preserves_deadline() {
        let code = encode_fresh(2, Some(5));
        let mut c = code;
        for i in 1..=10 {
            c = bumped(c);
            assert_eq!(freq_of(c), (i as u8).min(FREQ_MAX));
            assert_eq!(c & DEADLINE_MASK, code & DEADLINE_MASK);
            assert_eq!(c & TTL_BIT, TTL_BIT);
        }
    }

    #[test]
    fn slots_hit_bump_and_expire() {
        let cfg = LifecycleConfig::new(1);
        let clock = Arc::clone(&cfg.clock);
        let s = LifecycleSlots::standalone(cfg, 64);
        s.fresh(3, Some(2));
        assert!(s.on_hit(3));
        assert!(s.on_hit(3));
        assert_eq!(s.freq_at(3), 2);
        clock.advance(2);
        assert!(s.is_expired_at(3));
        assert!(!s.on_hit(3), "expired hit must report miss");
        assert_eq!(s.freq_at(3), 2, "expired hit must not bump");
        s.fresh(3, None);
        assert!(!s.is_expired_at(3));
        assert_eq!(s.freq_at(3), 0, "reclaim resets frequency");
    }

    #[test]
    fn refresh_extends_deadline_and_keeps_freq() {
        let cfg = LifecycleConfig::new(1);
        let clock = Arc::clone(&cfg.clock);
        let s = LifecycleSlots::colocated(cfg, 8);
        s.fresh(0, Some(1));
        assert!(s.on_hit(0));
        s.refresh(0, Some(5));
        clock.advance(3);
        assert!(!s.is_expired_at(0), "refreshed TTL outlives the original");
        assert_eq!(s.freq_at(0), 1, "refresh preserves frequency");
        clock.advance(2);
        assert!(s.is_expired_at(0));
    }

    #[test]
    fn move_code_carries_lifecycle() {
        let cfg = LifecycleConfig::new(1);
        let s = LifecycleSlots::standalone(cfg, 16);
        s.fresh(1, Some(4));
        assert!(s.on_hit(1));
        s.move_code(1, 9);
        assert_eq!(s.freq_at(9), 1);
        assert!(!s.is_expired_at(9));
        assert_eq!(s.code(1), 0);
    }

    #[test]
    fn standalone_slots_touch_their_own_lines_colocated_do_not() {
        let _measure = probes::measurement_section();
        probes::set_enabled(true);
        let cfg = LifecycleConfig::new(1);
        let st = LifecycleSlots::standalone(cfg.clone(), 256);
        let sc = probes::ProbeScope::begin();
        st.code(0);
        st.code(200); // second line of the standalone array
        assert_eq!(sc.finish(), 2);
        let co = LifecycleSlots::colocated(cfg, 256);
        let sc = probes::ProbeScope::begin();
        co.code(0);
        co.code(200);
        assert_eq!(sc.finish(), 0, "colocated codes ride the host's lines");
    }
}
