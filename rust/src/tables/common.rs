//! Shared open-addressing bucket storage.
//!
//! All open-addressing designs (double, p2, iceberg, cuckoo, warpcore,
//! slabhash-like) store key-value pairs in a flat [`SimMem`]: pair `i`
//! occupies slots `2i` (key) and `2i+1` (value), i.e. 16 bytes — the
//! paper's 8-byte-key / 8-byte-value configuration. A bucket of
//! `bucket_size` pairs is `bucket_size * 16` bytes; a DoubleHT bucket of
//! 8 pairs is exactly one 128-byte cache line, a 32-pair metadata bucket
//! spans 4 lines, matching §5.
//!
//! The scan routine walks a bucket in `tile_size`-pair chunks the way a
//! cooperative-group tile does, so probe accounting sees the same cache
//! lines the GPU tile would touch.

use super::meta::MetaArray;
use crate::gpusim::mem::{is_user_key, SimMem, EMPTY, RESERVED, TOMBSTONE};
use crate::gpusim::race::{RaceEvent, RaceHook};

pub use crate::gpusim::mem::{
    EMPTY as KEY_EMPTY, RESERVED as KEY_RESERVED, TOMBSTONE as KEY_TOMBSTONE,
};

/// Result of scanning one bucket for a key.
#[derive(Clone, Copy, Debug, Default)]
pub struct ScanResult {
    /// Slot (within bucket) and current value where `key` was found.
    pub found: Option<(usize, u64)>,
    /// First slot holding `EMPTY` (never used).
    pub first_empty: Option<usize>,
    /// First slot holding `TOMBSTONE` (deleted, reusable).
    pub first_tombstone: Option<usize>,
    /// Number of live (user-key or reserved) slots seen — the bucket fill
    /// used by power-of-two-choice placement.
    pub fill: usize,
}

impl ScanResult {
    /// First reusable slot: prefer a tombstone (keeps the "key at or
    /// before first EMPTY" invariant tight), else the first empty.
    #[inline]
    pub fn reusable(&self) -> Option<usize> {
        self.first_tombstone.or(self.first_empty)
    }

    /// True when the bucket contains a never-used slot — the probe
    /// sequence for any key mapping here can stop (negative early exit).
    #[inline]
    pub fn has_empty(&self) -> bool {
        self.first_empty.is_some()
    }
}

/// Free-slot worklist for one bucket, captured by a single shared scan
/// and consumed by a grouped (batch) insert. Tombstones are handed out
/// before never-used slots, matching [`ScanResult::reusable`]'s
/// preference; consuming slots does not change what the bucket held *at
/// scan time* (see [`FreeSlots::had_empty`]).
#[derive(Clone, Debug, Default)]
pub struct FreeSlots {
    tombstones: Vec<u16>,
    empties: Vec<u16>,
    cursor_t: usize,
    cursor_e: usize,
}

impl FreeSlots {
    #[inline]
    pub fn push_tombstone(&mut self, slot: usize) {
        self.tombstones.push(slot as u16);
    }

    #[inline]
    pub fn push_empty(&mut self, slot: usize) {
        self.empties.push(slot as u16);
    }

    /// Next candidate slot for a claim (tombstones first), or `None` when
    /// the scan-time list is exhausted.
    #[inline]
    pub fn next_free(&mut self) -> Option<usize> {
        if self.cursor_t < self.tombstones.len() {
            self.cursor_t += 1;
            return Some(self.tombstones[self.cursor_t - 1] as usize);
        }
        if self.cursor_e < self.empties.len() {
            self.cursor_e += 1;
            return Some(self.empties[self.cursor_e - 1] as usize);
        }
        None
    }

    /// Did the bucket hold at least one never-used slot at scan time?
    /// This is the negative-early-exit precondition: a key is always
    /// stored at or before the first EMPTY bucket of its probe sequence,
    /// so a scan-time EMPTY in the *first* bucket proves a scan-time miss
    /// there is a table-wide miss. Stays true after the group consumes
    /// the slots — the proof is about the scan instant.
    #[inline]
    pub fn had_empty(&self) -> bool {
        !self.empties.is_empty()
    }
}

/// Flat pair storage divided into buckets.
pub struct Pairs {
    mem: SimMem,
    pub bucket_size: usize,
    pub num_buckets: usize,
    pub tile_size: usize,
}

impl Pairs {
    /// `num_buckets` is rounded up to a power of two by the caller.
    pub fn new(num_buckets: usize, bucket_size: usize, tile_size: usize) -> Self {
        assert!(num_buckets.is_power_of_two(), "bucket count must be 2^k");
        Self {
            mem: SimMem::new(num_buckets * bucket_size * 2),
            bucket_size,
            num_buckets,
            tile_size: tile_size.max(1),
        }
    }

    #[inline(always)]
    pub fn mem(&self) -> &SimMem {
        &self.mem
    }

    #[inline(always)]
    pub fn mask(&self) -> u64 {
        (self.num_buckets - 1) as u64
    }

    /// Key-slot index of pair `slot` in `bucket`.
    #[inline(always)]
    pub fn kidx(&self, bucket: usize, slot: usize) -> usize {
        (bucket * self.bucket_size + slot) * 2
    }

    pub fn device_bytes(&self) -> usize {
        self.mem.bytes()
    }

    /// Scan the whole bucket for `key`, collecting empty/tombstone/fill
    /// info. Walks in tile-sized chunks (cache-line order).
    pub fn scan_bucket(&self, bucket: usize, key: u64, strong: bool) -> ScanResult {
        let mut r = ScanResult::default();
        let base = self.kidx(bucket, 0);
        let mut slot = 0;
        while slot < self.bucket_size {
            let chunk_end = (slot + self.tile_size).min(self.bucket_size);
            for s in slot..chunk_end {
                let k = self.mem.load(base + s * 2, strong);
                if k == key {
                    let v = self.mem.load(base + s * 2 + 1, strong);
                    r.found = Some((s, v));
                    return r; // found — tile exits
                } else if k == EMPTY {
                    if r.first_empty.is_none() {
                        r.first_empty = Some(s);
                    }
                } else if k == TOMBSTONE {
                    if r.first_tombstone.is_none() {
                        r.first_tombstone = Some(s);
                    }
                    // tombstones don't count toward fill
                } else {
                    // user key or RESERVED (pending publish): occupied
                    r.fill += 1;
                }
            }
            slot = chunk_end;
        }
        r
    }

    /// One shared pass over a bucket serving a whole batch group: for
    /// each key in `keys`, its `(slot, value-at-scan)` if present, plus
    /// the bucket's complete free-slot list and fill. The bucket's cache
    /// lines are walked ONCE regardless of group size — the CPU analog of
    /// a cooperative tile scanning a bucket one time for a warp's worth
    /// of batched operations. Unlike [`Pairs::scan_bucket`] there is no
    /// early exit: the group needs the full free list.
    ///
    /// `found` is cleared and filled parallel to `keys` (duplicate keys
    /// each receive the hit). Values are as of the scan; mutating callers
    /// must re-read before merge-style updates.
    pub fn scan_bucket_group(
        &self,
        bucket: usize,
        keys: &[u64],
        strong: bool,
        found: &mut Vec<Option<(usize, u64)>>,
    ) -> (FreeSlots, usize) {
        found.clear();
        found.resize(keys.len(), None);
        let mut free = FreeSlots::default();
        let mut fill = 0usize;
        let base = self.kidx(bucket, 0);
        for s in 0..self.bucket_size {
            let k = self.mem.load(base + s * 2, strong);
            if k == EMPTY {
                free.push_empty(s);
            } else if k == TOMBSTONE {
                free.push_tombstone(s);
            } else {
                // User key or RESERVED (pending publish): occupied.
                fill += 1;
                if is_user_key(k) {
                    // Single pass over the group's keys; the value loads
                    // lazily on the first match so misses keep the
                    // scalar scan's probe footprint.
                    let mut v: Option<u64> = None;
                    for (i, &q) in keys.iter().enumerate() {
                        if q == k {
                            let vv =
                                *v.get_or_insert_with(|| self.mem.load(base + s * 2 + 1, strong));
                            found[i] = Some((s, vv));
                        }
                    }
                }
            }
        }
        (free, fill)
    }

    /// Scan only the listed slots (metadata candidates) for `key`.
    pub fn scan_slots(
        &self,
        bucket: usize,
        slots: impl IntoIterator<Item = usize>,
        key: u64,
        strong: bool,
    ) -> Option<(usize, u64)> {
        let base = self.kidx(bucket, 0);
        for s in slots {
            let k = self.mem.load(base + s * 2, strong);
            if k == key {
                return Some((s, self.mem.load(base + s * 2 + 1, strong)));
            }
        }
        None
    }

    /// First free (EMPTY or TOMBSTONE) slot in the bucket, if any —
    /// tombstones preferred like [`ScanResult::reusable`].
    pub fn find_free(&self, bucket: usize, strong: bool) -> Option<usize> {
        let base = self.kidx(bucket, 0);
        let mut first_empty = None;
        for s in 0..self.bucket_size {
            let k = self.mem.load(base + s * 2, strong);
            if k == TOMBSTONE {
                return Some(s);
            }
            if k == EMPTY && first_empty.is_none() {
                first_empty = Some(s);
            }
        }
        first_empty
    }

    /// Try to claim `slot` in `bucket` (CAS EMPTY→RESERVED or, when
    /// `reuse_tombstone`, TOMBSTONE→RESERVED). On success the caller owns
    /// the slot and must [`Pairs::publish`].
    #[inline]
    pub fn try_claim(&self, bucket: usize, slot: usize, reuse_tombstone: bool) -> bool {
        let k = self.kidx(bucket, slot);
        if self.mem.cas(k, EMPTY, RESERVED).is_ok() {
            return true;
        }
        reuse_tombstone && self.mem.cas(k, TOMBSTONE, RESERVED).is_ok()
    }

    /// Publish `key → val` into a slot this thread has claimed.
    #[inline]
    pub fn publish(&self, bucket: usize, slot: usize, key: u64, val: u64) {
        self.mem.publish_pair(self.kidx(bucket, slot), key, val);
    }

    /// Write a pair NON-atomically (key first, value after — the
    /// Warpcore-style unsafe write the paper calls out: "insertions of
    /// key-value pairs are not atomic").
    #[inline]
    pub fn write_pair_unsafe(&self, bucket: usize, slot: usize, key: u64, val: u64) {
        let k = self.kidx(bucket, slot);
        self.mem.store_relaxed(k, key);
        self.mem.store_relaxed(k + 1, val);
    }

    /// Atomic accumulate into the value slot of a pair (u64).
    #[inline]
    pub fn value_fetch_add(&self, bucket: usize, slot: usize, v: u64) {
        self.mem.fetch_add(self.kidx(bucket, slot) + 1, v);
    }

    /// Atomic accumulate into the value slot of a pair (f64 bits).
    #[inline]
    pub fn value_fetch_add_f64(&self, bucket: usize, slot: usize, v: f64) {
        self.mem.fetch_add_f64(self.kidx(bucket, slot) + 1, v);
    }

    /// Store a new value for an existing pair.
    #[inline]
    pub fn value_store(&self, bucket: usize, slot: usize, v: u64) {
        self.mem.store_release(self.kidx(bucket, slot) + 1, v);
    }

    /// Read the key currently in a slot.
    #[inline]
    pub fn key_at(&self, bucket: usize, slot: usize, strong: bool) -> u64 {
        self.mem.load(self.kidx(bucket, slot), strong)
    }

    /// Read the pair at a slot via the vector-load analog.
    #[inline]
    pub fn pair_at(&self, bucket: usize, slot: usize, strong: bool) -> (u64, u64) {
        self.mem.load_pair(self.kidx(bucket, slot), strong)
    }

    /// Delete the pair at `slot` (key → TOMBSTONE). Caller must hold the
    /// serialization lock for this key.
    #[inline]
    pub fn kill(&self, bucket: usize, slot: usize) {
        self.mem.store_release(self.kidx(bucket, slot), TOMBSTONE);
    }

    /// Overwrite a slot's key directly (cuckoo move, under both locks).
    #[inline]
    pub fn set_pair_locked(&self, bucket: usize, slot: usize, key: u64, val: u64) {
        let k = self.kidx(bucket, slot);
        self.mem.store_relaxed(k + 1, val);
        self.mem.store_release(k, key);
    }

    /// Count copies of `key` across the entire storage (adversarial
    /// verification; O(capacity)).
    pub fn count_copies(&self, key: u64) -> usize {
        let mut n = 0;
        for b in 0..self.num_buckets {
            for s in 0..self.bucket_size {
                if self.mem.snapshot_raw(self.kidx(b, s)) == key {
                    n += 1;
                }
            }
        }
        n
    }

    /// Iterate all live pairs (quiesced snapshot; used for BSP export).
    pub fn for_each_live(&self, mut f: impl FnMut(u64, u64)) {
        self.for_each_live_indexed(|_, _, k, v| f(k, v));
    }

    /// [`Pairs::for_each_live`] with the `(bucket, slot)` coordinates of
    /// each pair, so lifecycle-aware callers can consult the entry's
    /// expiry code (stored per flat slot `bucket * bucket_size + slot`)
    /// and skip expired entries during migration/freeze collection.
    pub fn for_each_live_indexed(&self, mut f: impl FnMut(usize, usize, u64, u64)) {
        for b in 0..self.num_buckets {
            for s in 0..self.bucket_size {
                let k = self.mem.snapshot_raw(self.kidx(b, s));
                if is_user_key(k) {
                    f(b, s, k, self.mem.snapshot_raw(self.kidx(b, s) + 1));
                }
            }
        }
    }
}

/// Claim + publish `key → val` into bucket `b` from a group's shared
/// free-slot list — the one claim protocol every bulk-native design
/// uses (tag CAS first when metadata is present, exactly like the
/// scalar `claim_in_bucket` paths). Returns the claimed slot, or `None`
/// when the scan-time list is exhausted (CAS races with inserts from
/// other primary buckets may consume slots first) — the caller falls
/// back to its full scalar walk.
#[allow(clippy::too_many_arguments)]
pub(crate) fn claim_from_free(
    pairs: &Pairs,
    meta: Option<&MetaArray>,
    b: usize,
    free: &mut FreeSlots,
    key: u64,
    val: u64,
    tag: u16,
    hook: &dyn RaceHook,
) -> Option<usize> {
    while let Some(slot) = free.next_free() {
        hook.on_event(RaceEvent::BeforeClaim { key, bucket: b });
        if let Some(m) = meta {
            if m.try_claim(b, slot, tag, true) {
                let ok = pairs.try_claim(b, slot, true);
                debug_assert!(ok, "tag claimed but pair slot busy");
                pairs.publish(b, slot, key, val);
                return Some(slot);
            }
        } else if pairs.try_claim(b, slot, true) {
            pairs.publish(b, slot, key, val);
            return Some(slot);
        }
    }
    None
}

/// Round a requested slot capacity to (num_buckets pow2, bucket_size).
pub fn bucket_count_for(slots: usize, bucket_size: usize) -> usize {
    let want = slots.div_ceil(bucket_size).max(1);
    want.next_power_of_two()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pairs() -> Pairs {
        Pairs::new(8, 8, 4)
    }

    #[test]
    fn bucket_count_rounds_to_pow2() {
        assert_eq!(bucket_count_for(100, 8), 16);
        assert_eq!(bucket_count_for(128, 8), 16);
        assert_eq!(bucket_count_for(129, 8), 32);
        assert_eq!(bucket_count_for(1, 8), 1);
    }

    #[test]
    fn claim_publish_find() {
        let p = pairs();
        assert!(p.try_claim(3, 2, false));
        p.publish(3, 2, 42, 99);
        let r = p.scan_bucket(3, 42, true);
        assert_eq!(r.found, Some((2, 99)));
    }

    #[test]
    fn scan_tracks_empty_tombstone_fill() {
        let p = pairs();
        assert!(p.try_claim(0, 0, false));
        p.publish(0, 0, 10, 1);
        assert!(p.try_claim(0, 1, false));
        p.publish(0, 1, 20, 2);
        p.kill(0, 1);
        let r = p.scan_bucket(0, 999, true);
        assert!(r.found.is_none());
        assert_eq!(r.first_empty, Some(2));
        assert_eq!(r.first_tombstone, Some(1));
        assert_eq!(r.fill, 1);
        assert_eq!(r.reusable(), Some(1)); // prefers tombstone
        assert!(r.has_empty());
    }

    #[test]
    fn claim_respects_tombstone_flag() {
        let p = pairs();
        assert!(p.try_claim(1, 0, false));
        p.publish(1, 0, 7, 7);
        p.kill(1, 0);
        assert!(!p.try_claim(1, 0, false), "tombstone without reuse");
        assert!(p.try_claim(1, 0, true), "tombstone with reuse");
    }

    #[test]
    fn double_claim_fails() {
        let p = pairs();
        assert!(p.try_claim(2, 5, false));
        assert!(!p.try_claim(2, 5, false));
        assert!(!p.try_claim(2, 5, true));
    }

    #[test]
    fn count_copies_spans_buckets() {
        let p = pairs();
        for b in [1usize, 4, 7] {
            assert!(p.try_claim(b, 0, false));
            p.publish(b, 0, 55, b as u64);
        }
        assert_eq!(p.count_copies(55), 3);
        assert_eq!(p.count_copies(56), 0);
    }

    #[test]
    fn for_each_live_skips_sentinels() {
        let p = pairs();
        assert!(p.try_claim(0, 0, false));
        p.publish(0, 0, 5, 50);
        assert!(p.try_claim(0, 1, false));
        p.publish(0, 1, 6, 60);
        p.kill(0, 1);
        let mut seen = vec![];
        p.for_each_live(|k, v| seen.push((k, v)));
        assert_eq!(seen, vec![(5, 50)]);
    }

    #[test]
    fn group_scan_matches_scalar_scan() {
        let p = pairs();
        assert!(p.try_claim(2, 1, false));
        p.publish(2, 1, 11, 101);
        assert!(p.try_claim(2, 3, false));
        p.publish(2, 3, 22, 202);
        assert!(p.try_claim(2, 4, false));
        p.publish(2, 4, 33, 303);
        p.kill(2, 4); // tombstone at slot 4
        let keys = vec![22, 99, 11, 22];
        let mut found = Vec::new();
        let (mut free, fill) = p.scan_bucket_group(2, &keys, true, &mut found);
        assert_eq!(found[0], Some((3, 202)));
        assert_eq!(found[1], None);
        assert_eq!(found[2], Some((1, 101)));
        assert_eq!(found[3], Some((3, 202)), "duplicate keys each get the hit");
        assert_eq!(fill, 2);
        assert!(free.had_empty());
        // Tombstone handed out before empties, then ascending empties.
        assert_eq!(free.next_free(), Some(4));
        assert_eq!(free.next_free(), Some(0));
        assert_eq!(free.next_free(), Some(2));
        // Consuming slots never invalidates the scan-time empty proof.
        assert!(free.had_empty());
        while free.next_free().is_some() {}
        assert!(free.had_empty());
    }

    #[test]
    fn value_ops() {
        let p = pairs();
        assert!(p.try_claim(0, 0, false));
        p.publish(0, 0, 5, 10);
        p.value_fetch_add(0, 0, 7);
        assert_eq!(p.scan_bucket(0, 5, true).found, Some((0, 17)));
        p.value_store(0, 0, 3);
        assert_eq!(p.scan_bucket(0, 5, true).found, Some((0, 3)));
    }
}
