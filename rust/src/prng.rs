//! Deterministic pseudo-random number generation substrate.
//!
//! The offline build has no `rand` crate, and the paper's benchmarking
//! framework needs reproducible workloads anyway (uniform-random keys,
//! Zipfian request streams for YCSB, shuffles for aging slices), so we
//! implement the generators ourselves:
//!
//! - [`SplitMix64`] — seed expander, passes BigCrush, used to seed others.
//! - [`Xoshiro256pp`] — general-purpose stream generator (xoshiro256++).
//! - [`Zipfian`] — YCSB-style Zipfian distribution over `n` items using the
//!   Gray/Jain rejection-inversion-free algorithm from the YCSB core
//!   (`ZipfianGenerator`), with the standard `theta = 0.99`.
//!
//! All generators are `Send` and cheap to fork per thread.

/// SplitMix64: Steele, Lea & Flood. Used to derive seeds and as a
/// lightweight standalone generator.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — David Blackman and Sebastiano Vigna (public domain).
#[derive(Clone, Debug)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    /// Seed via SplitMix64 as recommended by the xoshiro authors.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift (no modulo bias
    /// beyond 2^-64, fine for benchmarks).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform double in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fisher–Yates shuffle.
    #[cfg(test)] // test-only surface (warpspeed-analyze WS3)
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        if xs.is_empty() {
            return;
        }
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

/// YCSB-style Zipfian generator (theta = 0.99 by default).
///
/// Port of the classic Gray et al. "Quickly generating billion-record
/// synthetic databases" algorithm as used by the YCSB core workload
/// generator. Items are ranks `0..n`; rank 0 is the hottest.
#[derive(Clone, Debug)]
pub struct Zipfian {
    n: u64,
    theta: f64,
    alpha: f64,
    zetan: f64,
    eta: f64,
    zeta2theta: f64,
    rng: Xoshiro256pp,
}

impl Zipfian {
    pub const DEFAULT_THETA: f64 = 0.99;

    pub fn new(n: u64, seed: u64) -> Self {
        Self::with_theta(n, Self::DEFAULT_THETA, seed)
    }

    pub fn with_theta(n: u64, theta: f64, seed: u64) -> Self {
        assert!(n > 0);
        let zetan = Self::zeta(n, theta);
        let zeta2theta = Self::zeta(2, theta);
        let alpha = 1.0 / (1.0 - theta);
        let eta = (1.0 - (2.0 / n as f64).powf(1.0 - theta)) / (1.0 - zeta2theta / zetan);
        Self {
            n,
            theta,
            alpha,
            zetan,
            eta,
            zeta2theta,
            rng: Xoshiro256pp::new(seed),
        }
    }

    fn zeta(n: u64, theta: f64) -> f64 {
        // Direct sum; n is at most the table universe which we keep <= ~1e8
        // in this reproduction. For the default bench sizes (<= ~1e7) this
        // is fast enough and matches YCSB exactly.
        let mut sum = 0.0;
        for i in 1..=n {
            sum += 1.0 / (i as f64).powf(theta);
        }
        sum
    }

    /// Next rank in `[0, n)`; rank 0 is hottest.
    pub fn next_rank(&mut self) -> u64 {
        let u = self.rng.next_f64();
        let uz = u * self.zetan;
        if uz < 1.0 {
            return 0;
        }
        if uz < 1.0 + 0.5f64.powf(self.theta) {
            return 1;
        }
        let _ = self.zeta2theta;
        ((self.n as f64) * (self.eta * u - self.eta + 1.0).powf(self.alpha)) as u64
    }

    /// YCSB "scrambled zipfian": spread the hot ranks across the key space
    /// deterministically so hot keys are not clustered.
    pub fn next_scrambled(&mut self) -> u64 {
        let rank = self.next_rank();
        fnv64(rank) % self.n
    }
}

/// FNV-1a 64-bit, used for scrambled-Zipfian spreading (matches YCSB).
#[inline]
pub fn fnv64(x: u64) -> u64 {
    let mut hash: u64 = 0xCBF29CE484222325;
    let mut v = x;
    for _ in 0..8 {
        let octet = v & 0xff;
        v >>= 8;
        hash ^= octet;
        hash = hash.wrapping_mul(0x100000001B3);
    }
    hash
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splitmix_known_vector() {
        // Reference values for seed 1234567 from the canonical C impl.
        let mut sm = SplitMix64::new(0);
        let first = sm.next_u64();
        assert_eq!(first, 0xE220A8397B1DCDAF);
    }

    #[test]
    fn xoshiro_differs_across_seeds() {
        let mut a = Xoshiro256pp::new(1);
        let mut b = Xoshiro256pp::new(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn next_below_in_range() {
        let mut r = Xoshiro256pp::new(7);
        for bound in [1u64, 2, 3, 10, 1000, u32::MAX as u64] {
            for _ in 0..200 {
                assert!(r.next_below(bound) < bound);
            }
        }
    }

    #[test]
    fn next_f64_in_unit_interval() {
        let mut r = Xoshiro256pp::new(9);
        for _ in 0..1000 {
            let f = r.next_f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256pp::new(11);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
    }

    #[test]
    fn zipfian_ranks_in_range_and_skewed() {
        let n = 1000;
        let mut z = Zipfian::new(n, 3);
        let mut counts = vec![0u64; n as usize];
        let draws = 100_000;
        for _ in 0..draws {
            let r = z.next_rank();
            assert!(r < n, "rank {r} out of range");
            counts[r as usize] += 1;
        }
        // Rank 0 should dominate: > 5% of mass for theta=0.99, n=1000.
        assert!(counts[0] as f64 / draws as f64 > 0.05);
        // And be much hotter than the median rank.
        assert!(counts[0] > 20 * counts[500].max(1));
    }

    #[test]
    fn zipfian_scrambled_in_range() {
        let mut z = Zipfian::new(12345, 5);
        for _ in 0..10_000 {
            assert!(z.next_scrambled() < 12345);
        }
    }

    #[test]
    fn fnv_spreads() {
        // Consecutive inputs should map to very different outputs.
        let a = fnv64(0);
        let b = fnv64(1);
        assert!(a != b && (a ^ b).count_ones() > 8);
    }
}
