//! Sparse tensor contraction (SpTC) — paper §6.7, Table 6.1.
//!
//! Follows SPARTA's [32] data layout and operations: inputs are two COO
//! tensors X and Y plus the list of modes to contract. Y is loaded into a
//! hash *multimap* keyed by its contracted-mode indices; every nonzero of
//! X is matched against that map; matched pairs emit an output nonzero
//! keyed by the concatenated free modes of X and Y whose values are
//! *accumulated* with an upsert — the compound operation the paper argues
//! existing GPU tables cannot express.
//!
//! Stability fast path: on stable tables the accumulation uses the
//! lock-free in-place `atomicAdd` (`fetch_add_f64_in_place`), falling back
//! to a locked upsert only on first touch; unstable tables (CuckooHT) pay
//! a locked upsert per accumulation — this is the paper's "DoubleHT and
//! P2HT(M) are up to 50% faster due to stability" mechanism.
//!
//! The Y multimap: the table maps `packed contracted index → (1 + head)`
//! where `head` indexes a per-tensor chain array (`next[]`) threading all
//! Y nonzeros sharing the key — SPARTA's bucketed layout expressed through
//! the paper's upsert-with-callback API.
//!
//! The FROSTT NIPS tensor is download-gated; [`synthetic_nips`] generates
//! a COO tensor with the NIPS shape/density characteristics (see DESIGN.md
//! §Substitutions).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::prng::Xoshiro256pp;
use crate::tables::{ConcurrentMap, UpsertOp, UpsertResult};

/// Max tensor order we support (NIPS is order 4).
pub const MAX_MODES: usize = 4;

/// Coordinate-format sparse tensor.
#[derive(Clone, Debug)]
pub struct CooTensor {
    pub dims: Vec<u64>,
    /// One `[u32; MAX_MODES]` coordinate per nonzero (unused modes 0).
    pub coords: Vec<[u32; MAX_MODES]>,
    pub values: Vec<f64>,
}

impl CooTensor {
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn order(&self) -> usize {
        self.dims.len()
    }

    /// Pack the given modes of coordinate `i` into a non-zero u64 key
    /// (mixed radix over the selected dims, +1 to avoid the EMPTY key).
    pub fn pack(&self, i: usize, modes: &[usize]) -> u64 {
        let mut key: u64 = 0;
        for &m in modes {
            key = key * self.dims[m] + self.coords[i][m] as u64;
        }
        key + 1
    }
}

/// Synthetic NIPS-like tensor: shape scaled from FROSTT NIPS
/// (2482 × 2862 × 14036 × 17, 3.1M nnz) by `scale` ∈ (0, 1].
pub fn synthetic_nips(scale: f64, seed: u64) -> CooTensor {
    let dims: Vec<u64> = [2482.0, 2862.0, 14036.0, 17.0]
        .iter()
        .map(|d| ((d * scale).ceil() as u64).max(2))
        .collect();
    let nnz = ((3_101_609.0 * scale * scale) as usize).max(100);
    let mut rng = Xoshiro256pp::new(seed);
    let mut coords = Vec::with_capacity(nnz);
    let mut values = Vec::with_capacity(nnz);
    let mut seen = std::collections::HashSet::with_capacity(nnz * 2);
    while coords.len() < nnz {
        // Mode-3 (17-wide) is dense-ish; others uniform — mirrors the
        // "word × doc × year" clustering of NIPS loosely by biasing mode 0
        // toward a Zipf-ish head so contraction hits real collisions.
        let c = [
            (rng.next_below(dims[0]) * rng.next_below(dims[0]) / dims[0].max(1)) as u32,
            rng.next_below(dims[1]) as u32,
            rng.next_below(dims[2]) as u32,
            rng.next_below(dims[3]) as u32,
        ];
        if seen.insert(c) {
            coords.push(c);
            values.push((rng.next_f64() - 0.5) * 4.0);
        }
    }
    CooTensor {
        dims,
        coords,
        values,
    }
}

/// Complement of `modes` in `0..order`.
fn free_modes(order: usize, contracted: &[usize]) -> Vec<usize> {
    (0..order).filter(|m| !contracted.contains(m)).collect()
}

/// Result + counters of one contraction run.
pub struct ContractionResult {
    /// Output table: packed (free_x ++ free_y) index → f64 bits.
    pub output: Arc<dyn ConcurrentMap>,
    pub matches: u64,
    pub fast_path_adds: u64,
    pub slow_path_upserts: u64,
}

impl ContractionResult {
    /// Materialize the output as (key, value) pairs.
    #[cfg(test)] // test-only surface (warpspeed-analyze WS3)
    pub fn to_pairs(&self) -> Vec<(u64, f64)> {
        let mut out = Vec::new();
        self.output
            .for_each_entry(&mut |k, v| out.push((k, f64::from_bits(v))));
        out.sort_unstable_by_key(|&(k, _)| k);
        out
    }

    /// Checksum for validation against the CPU baseline.
    pub fn checksum(&self) -> f64 {
        let mut s = 0.0;
        self.output
            .for_each_entry(&mut |_, v| s += f64::from_bits(v));
        s
    }
}

/// Contract `x` with `y` over the given mode lists using hash tables of
/// the provided builder (SPARTA's algorithm; `cmodes_x.len() ==
/// cmodes_y.len()` and dims must agree).
pub fn contract(
    x: &CooTensor,
    y: &CooTensor,
    cmodes_x: &[usize],
    cmodes_y: &[usize],
    y_table: Arc<dyn ConcurrentMap>,
    out_table: Arc<dyn ConcurrentMap>,
) -> ContractionResult {
    assert_eq!(cmodes_x.len(), cmodes_y.len());
    for (&mx, &my) in cmodes_x.iter().zip(cmodes_y) {
        assert_eq!(x.dims[mx], y.dims[my], "contracted dims must match");
    }
    let free_x = free_modes(x.order(), cmodes_x);
    let free_y = free_modes(y.order(), cmodes_y);

    // ---- Phase 1: load Y into the multimap (chain via next[]). ----
    let next: Vec<AtomicU64> = (0..y.nnz()).map(|_| AtomicU64::new(0)).collect();
    for i in 0..y.nnz() {
        let key = y.pack(i, cmodes_y);
        // Chain-push: new head = i+1, next[i] = previous head. The Custom
        // callback runs under the key's bucket lock, so the push is
        // atomic per key.
        let push = |old: u64, new: u64| {
            next[(new - 1) as usize].store(old, Ordering::Release);
            new
        };
        let r = y_table.upsert(key, (i + 1) as u64, &UpsertOp::Custom(&push));
        assert_ne!(r, UpsertResult::Full, "Y table overflow — size it larger");
    }

    // ---- Phase 2: stream X, match, accumulate. ----
    let mut matches = 0u64;
    let mut fast = 0u64;
    let mut slow = 0u64;
    let out_dims_y: u64 = free_y.iter().map(|&m| y.dims[m]).product::<u64>().max(1);
    for i in 0..x.nnz() {
        let key = x.pack(i, cmodes_x);
        let Some(head) = y_table.query(key) else {
            continue;
        };
        let x_part = x.pack(i, &free_x) - 1; // un-offset
        let mut cur = head;
        while cur != 0 {
            let j = (cur - 1) as usize;
            matches += 1;
            let y_part = y.pack(j, &free_y) - 1;
            let out_key = x_part * out_dims_y + y_part + 1;
            let prod = x.values[i] * y.values[j];
            // Stability fast path: in-place atomicAdd without locks.
            if out_table.fetch_add_f64_in_place(out_key, prod) {
                fast += 1;
            } else {
                let r = out_table.upsert(out_key, prod.to_bits(), &UpsertOp::AddAssignF64);
                assert_ne!(r, UpsertResult::Full, "output table overflow");
                slow += 1;
            }
            cur = next[j].load(Ordering::Acquire);
        }
    }
    ContractionResult {
        output: out_table,
        matches,
        fast_path_adds: fast,
        slow_path_upserts: slow,
    }
}

/// SPARTA-style CPU baseline: per-thread accumulators merged at the end
/// (sequential here — the merge structure is what we validate against).
pub fn contract_cpu_baseline(
    x: &CooTensor,
    y: &CooTensor,
    cmodes_x: &[usize],
    cmodes_y: &[usize],
) -> std::collections::HashMap<u64, f64> {
    let free_x = free_modes(x.order(), cmodes_x);
    let free_y = free_modes(y.order(), cmodes_y);
    let mut y_map: std::collections::HashMap<u64, Vec<usize>> = std::collections::HashMap::new();
    for j in 0..y.nnz() {
        y_map.entry(y.pack(j, cmodes_y)).or_default().push(j);
    }
    let out_dims_y: u64 = free_y.iter().map(|&m| y.dims[m]).product::<u64>().max(1);
    let mut acc: std::collections::HashMap<u64, f64> = std::collections::HashMap::new();
    for i in 0..x.nnz() {
        if let Some(js) = y_map.get(&x.pack(i, cmodes_x)) {
            let x_part = x.pack(i, &free_x) - 1;
            for &j in js {
                let y_part = y.pack(j, &free_y) - 1;
                let out_key = x_part * out_dims_y + y_part + 1;
                *acc.entry(out_key).or_insert(0.0) += x.values[i] * y.values[j];
            }
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tables::{build_table, TableKind};

    fn tiny_tensor() -> CooTensor {
        synthetic_nips(0.02, 42)
    }

    #[test]
    fn synthetic_nips_shape() {
        let t = synthetic_nips(0.05, 1);
        assert_eq!(t.order(), 4);
        assert!(t.nnz() >= 100);
        for (i, c) in t.coords.iter().enumerate() {
            for m in 0..4 {
                assert!((c[m] as u64) < t.dims[m], "coord {i} out of range");
            }
        }
    }

    #[test]
    fn pack_is_injective_within_dims() {
        let t = tiny_tensor();
        let mut seen = std::collections::HashMap::new();
        for i in 0..t.nnz() {
            let k = t.pack(i, &[0, 1, 2, 3]);
            assert!(k > 0);
            if let Some(prev) = seen.insert(k, i) {
                panic!("pack collision between nnz {prev} and {i}");
            }
        }
    }

    #[test]
    fn contraction_matches_cpu_baseline_1mode() {
        let t = tiny_tensor();
        for kind in [TableKind::Double, TableKind::P2Meta, TableKind::Chaining] {
            let yt = build_table(kind, t.nnz() * 2 + 1024);
            let ot = build_table(kind, t.nnz() * 8 + 1024);
            let r = contract(&t, &t, &[2], &[2], yt, ot);
            let base = contract_cpu_baseline(&t, &t, &[2], &[2]);
            assert!(r.matches > 0, "{kind:?}: no matches");
            let got = r.to_pairs();
            assert_eq!(got.len(), base.len(), "{kind:?}: nnz mismatch");
            for (k, v) in &got {
                let want = base.get(k).copied().unwrap_or(f64::NAN);
                assert!(
                    (v - want).abs() < 1e-9 * (1.0 + want.abs()),
                    "{kind:?}: key {k} value {v} != {want}"
                );
            }
        }
    }

    #[test]
    fn contraction_matches_cpu_baseline_3mode() {
        let t = tiny_tensor();
        let yt = build_table(TableKind::Double, t.nnz() * 2 + 1024);
        let ot = build_table(TableKind::Double, t.nnz() * 8 + 1024);
        let r = contract(&t, &t, &[0, 1, 3], &[0, 1, 3], yt, ot);
        let base = contract_cpu_baseline(&t, &t, &[0, 1, 3], &[0, 1, 3]);
        let sum: f64 = base.values().sum();
        assert!((r.checksum() - sum).abs() < 1e-6 * (1.0 + sum.abs()));
    }

    #[test]
    fn stable_tables_use_fast_path() {
        // Contract over modes [0,1,2] so the output collapses onto the
        // tiny mode-3 index space — heavy accumulation, which is where
        // stability pays (in-place atomicAdd, no locks).
        let t = tiny_tensor();
        let yt = build_table(TableKind::P2, t.nnz() * 2 + 1024);
        let ot = build_table(TableKind::P2, t.nnz() * 8 + 1024);
        let r = contract(&t, &t, &[0, 1, 2], &[0, 1, 2], yt, ot);
        assert!(
            r.fast_path_adds > r.slow_path_upserts,
            "stable table should mostly hit the lock-free path \
             (fast={} slow={})",
            r.fast_path_adds,
            r.slow_path_upserts
        );
        // And the result still matches the baseline.
        let base = contract_cpu_baseline(&t, &t, &[0, 1, 2], &[0, 1, 2]);
        let sum: f64 = base.values().sum();
        assert!((r.checksum() - sum).abs() < 1e-6 * (1.0 + sum.abs()));
    }

    #[test]
    fn unstable_tables_fall_back_to_locked_upserts() {
        let t = tiny_tensor();
        let yt = build_table(TableKind::Cuckoo, t.nnz() * 2 + 1024);
        let ot = build_table(TableKind::Cuckoo, t.nnz() * 8 + 1024);
        let r = contract(&t, &t, &[2], &[2], yt, ot);
        assert_eq!(r.fast_path_adds, 0, "cuckoo has no in-place fast path");
        assert!(r.slow_path_upserts > 0);
        // Still correct, just slower.
        let base = contract_cpu_baseline(&t, &t, &[2], &[2]);
        let sum: f64 = base.values().sum();
        assert!((r.checksum() - sum).abs() < 1e-6 * (1.0 + sum.abs()));
    }
}
