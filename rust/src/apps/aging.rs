//! Aging workload driver (paper §6.5, Figure 6.2).
//!
//! "All hash tables are first filled to 85% load factor and then items are
//! inserted and removed in a set pattern... In each iteration, a new slice
//! of data equal to 1% of the total keys is inserted and the oldest 1% of
//! keys are removed. Queries are split into positive and negative queries,
//! and a 1% slice of known positive and negative keys are queried."
//!
//! The driver owns the FIFO window of live keys and exposes one
//! [`AgingDriver::run_iteration`] per benchmark tick, reporting per-kind
//! operation counts so the harness can compute per-iteration throughput
//! and probe counts exactly like Figure 6.2 / Table 5.1 (aging columns).

use std::sync::Arc;

use crate::tables::{ConcurrentMap, UpsertOp, UpsertResult};
use crate::workloads::keys::distinct_keys;

pub struct AgingDriver {
    table: Arc<dyn ConcurrentMap>,
    /// All keys that will ever exist, in insertion order.
    universe: Vec<u64>,
    /// Keys guaranteed never inserted (negative-query pool).
    negatives: Vec<u64>,
    /// FIFO window [oldest, next) of live keys.
    oldest: usize,
    next: usize,
    /// Slice size per iteration (1% of live set).
    pub slice: usize,
}

/// Operation counts of one aging iteration (for throughput accounting).
#[derive(Clone, Copy, Debug, Default)]
pub struct IterationOps {
    pub inserts: u64,
    pub insert_fails: u64,
    pub pos_queries: u64,
    pub pos_misses: u64,
    pub neg_queries: u64,
    pub neg_hits: u64,
    pub deletes: u64,
    pub delete_misses: u64,
}

impl IterationOps {
    pub fn total(&self) -> u64 {
        self.inserts + self.pos_queries + self.neg_queries + self.deletes
    }
}

impl AgingDriver {
    /// Fill `table` to 85% load factor; reserve enough fresh keys for
    /// `max_iterations` churn slices.
    pub fn new(table: Arc<dyn ConcurrentMap>, max_iterations: usize, seed: u64) -> Self {
        let fill = (table.capacity() as f64 * 0.85) as usize;
        Self::with_fill(table, max_iterations, seed, fill)
    }

    /// Like [`AgingDriver::new`] with an explicit live-window size. A
    /// `fill` beyond the table's nominal capacity ages a growable table
    /// past its provisioning (the growth benchmark's aging shape); on a
    /// fixed table the surplus inserts simply fail at saturation.
    pub fn with_fill(
        table: Arc<dyn ConcurrentMap>,
        max_iterations: usize,
        seed: u64,
        fill: usize,
    ) -> Self {
        let slice = (fill / 100).max(1);
        let universe = distinct_keys(fill + (max_iterations + 2) * slice, seed);
        let negatives = distinct_keys(slice.max(1), seed ^ 0xFFFF_AAAA)
            .into_iter()
            .filter(|k| !universe.contains(k))
            .collect();
        let mut d = Self {
            table,
            universe,
            negatives,
            oldest: 0,
            next: 0,
            slice,
        };
        for _ in 0..fill {
            d.insert_next();
        }
        d
    }

    fn insert_next(&mut self) -> bool {
        if self.next >= self.universe.len() {
            return false;
        }
        let k = self.universe[self.next];
        let r = self.table.upsert(k, k ^ 0xA9, &UpsertOp::InsertIfUnique);
        if r == UpsertResult::Inserted {
            self.next += 1;
            true
        } else {
            false
        }
    }

    /// Number of live keys in the FIFO window.
    pub fn live(&self) -> usize {
        self.next - self.oldest
    }

    /// Instrumented-mode accessor: insert the next fresh key (used by the
    /// probe-counting harness to wrap individual ops in probe scopes).
    pub fn insert_next_public(&mut self) -> bool {
        self.insert_next()
    }

    /// Instrumented-mode accessor: some live key, salted for spread.
    pub fn live_key(&self, salt: usize) -> u64 {
        let live = self.live().max(1);
        self.universe[self.oldest + (salt * 7919) % live]
    }

    /// Instrumented-mode accessor: pop the oldest live key (caller erases).
    pub fn pop_oldest_key(&mut self) -> Option<u64> {
        if self.oldest >= self.next {
            return None;
        }
        let k = self.universe[self.oldest];
        self.oldest += 1;
        Some(k)
    }

    /// One aging iteration: insert a slice, query positive + negative
    /// slices, delete the oldest slice. Returns the op counts.
    pub fn run_iteration(&mut self, iter_idx: usize) -> IterationOps {
        let mut ops = IterationOps::default();
        // Insert 1% fresh keys.
        for _ in 0..self.slice {
            ops.inserts += 1;
            if !self.insert_next() {
                ops.insert_fails += 1;
            }
        }
        // Positive queries: a 1% slice of live keys spread over the window.
        let live = self.live().max(1);
        for i in 0..self.slice {
            let idx = self.oldest + (i * 7919 + iter_idx) % live;
            let k = self.universe[idx];
            ops.pos_queries += 1;
            if self.table.query(k).is_none() {
                ops.pos_misses += 1;
            }
        }
        // Negative queries: keys never inserted.
        for i in 0..self.slice {
            let k = self.negatives[i % self.negatives.len()];
            ops.neg_queries += 1;
            if self.table.query(k).is_some() {
                ops.neg_hits += 1;
            }
        }
        // Delete the oldest 1%.
        for _ in 0..self.slice {
            if self.oldest >= self.next {
                break;
            }
            let k = self.universe[self.oldest];
            ops.deletes += 1;
            if !self.table.erase(k) {
                ops.delete_misses += 1;
            }
            self.oldest += 1;
        }
        ops
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tables::{build_table, TableKind};

    #[test]
    fn aging_preserves_correctness_for_all_concurrent_designs() {
        for kind in TableKind::CONCURRENT {
            let t = build_table(kind, 4096);
            let mut d = AgingDriver::new(t, 30, 0xA61);
            for it in 0..30 {
                let ops = d.run_iteration(it);
                assert_eq!(
                    ops.pos_misses, 0,
                    "{kind:?}: live key missing at iteration {it}"
                );
                assert_eq!(ops.neg_hits, 0, "{kind:?}: phantom key at iteration {it}");
                assert_eq!(
                    ops.delete_misses, 0,
                    "{kind:?}: delete lost a key at iteration {it}"
                );
            }
        }
    }

    #[test]
    fn overfilled_window_ages_a_growable_table_past_nominal() {
        use crate::tables::{GrowableMap, GrowthPolicy, TableConfig};
        let t = std::sync::Arc::new(GrowableMap::new(
            TableKind::P2Meta,
            TableConfig::for_kind(TableKind::P2Meta, 1024),
            GrowthPolicy {
                migration_batch: 16,
                ..Default::default()
            },
        ));
        let nominal = t.capacity();
        let fill = nominal * 2; // live window at 2× the provisioning
        let mut d = AgingDriver::with_fill(
            std::sync::Arc::clone(&t) as std::sync::Arc<dyn ConcurrentMap>,
            20,
            0xA63,
            fill,
        );
        assert_eq!(d.live(), fill, "growable prefill must not drop inserts");
        for it in 0..20 {
            let ops = d.run_iteration(it);
            assert_eq!(ops.insert_fails, 0, "growable aging rejected at iteration {it}");
            assert_eq!(ops.pos_misses, 0, "live key missing at iteration {it}");
            assert_eq!(ops.neg_hits, 0, "phantom key at iteration {it}");
            assert_eq!(ops.delete_misses, 0, "delete lost a key at iteration {it}");
        }
        assert!(t.quiesce_migration());
        assert!(t.grow_events() >= 1, "window 2× nominal must force growth");
        assert!(t.capacity() >= nominal * 2);
    }

    #[test]
    fn window_stays_near_85_percent() {
        let t = build_table(TableKind::Double, 4096);
        let cap = t.capacity();
        let mut d = AgingDriver::new(t, 20, 7);
        let expected = (cap as f64 * 0.85) as usize;
        assert!(d.live() >= expected * 98 / 100);
        for it in 0..20 {
            d.run_iteration(it);
        }
        // Inserts == deletes per iteration → live set stays flat (modulo
        // insert failures at saturation).
        assert!(d.live() >= expected * 95 / 100 && d.live() <= expected * 105 / 100);
    }
}
