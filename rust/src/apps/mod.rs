//! Downstream applications (paper §6.5–§6.8 and §4.1):
//! aging churn, GPU-cache-over-host-store, sparse tensor contraction, and
//! the adversarial correctness benchmark.

pub mod adversarial;
pub mod aging;
pub mod caching;
pub mod sptc;
