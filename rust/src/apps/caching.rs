//! GPU caching workload (paper §6.6, Figure 6.3).
//!
//! "The hash table resides on the GPU, while a key-value buffer remains on
//! the CPU. Queries first check the GPU hash table; if a key is missing,
//! it is retrieved from the CPU and inserted into the GPU, evicting an
//! entry in FIFO order if necessary. ... A ring queue, set to 85% of the
//! hash table size, ensures the table's maximum load factor never exceeds
//! 85%."
//!
//! The design exploits *stability*: the hot path is a fused
//! query-or-insert with in-place value access and no table-wide locking.
//! CuckooHT is not stable and "is unable to run this benchmark" — we
//! enforce the same restriction via [`ConcurrentMap::is_stable`].

use std::collections::VecDeque;
use std::sync::Arc;

use crate::tables::{ConcurrentMap, TieredMap, UpsertOp, UpsertResult};

/// Fraction of table capacity the FIFO ring may occupy (paper §6.6).
const RING_FRACTION: f64 = 0.85;

/// Host-side backing store: the full dataset (simulating CPU DRAM).
pub struct HostStore {
    map: std::collections::HashMap<u64, u64>,
}

impl HostStore {
    pub fn new(pairs: impl IntoIterator<Item = (u64, u64)>) -> Self {
        Self {
            map: pairs.into_iter().collect(),
        }
    }

    #[inline]
    pub fn fetch(&self, key: u64) -> Option<u64> {
        self.map.get(&key).copied()
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// FIFO cache of a [`HostStore`] in a device hash table.
pub struct GpuCache {
    table: Arc<dyn ConcurrentMap>,
    store: HostStore,
    /// FIFO ring of resident keys, capped at 85% of table capacity
    /// (recomputed from the live capacity in growth mode).
    ring: VecDeque<u64>,
    ring_cap: usize,
    /// Growth mode: the device table grows online instead of evicting —
    /// the ring cap follows the grown capacity, so saturation triggers
    /// a 2× growth rather than the Full-eviction-retry contortion.
    grow: bool,
    /// Freeze knob ([`GpuCache::with_tiered`]): cooldown ends by
    /// snapshotting the surviving residents into the device table's
    /// frozen read-optimized tier, so the post-cooldown steady state
    /// serves its (cold, read-mostly) hits at ~1 probe/op.
    freeze_on_cooldown: bool,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

impl GpuCache {
    /// Returns `None` when the table design cannot run this workload
    /// (unstable tables — the paper's CuckooHT case).
    pub fn new(table: Arc<dyn ConcurrentMap>, store: HostStore) -> Option<Self> {
        Self::with_mode(table, store, false)
    }

    /// Growth-mode cache over a growable table
    /// ([`crate::tables::GrowableMap`]): instead of FIFO-evicting at 85%
    /// of a fixed capacity, the device table grows 2× online and keeps
    /// admitting — the paper's §6.6 chaining observation (a 10% cache
    /// growing toward 28% of the dataset) reproduced through real growth
    /// rather than Full-driven eviction churn. Returns `None` for
    /// unstable or fixed-capacity tables.
    pub fn with_growth(table: Arc<dyn ConcurrentMap>, store: HostStore) -> Option<Self> {
        if !table.can_grow() {
            return None;
        }
        Self::with_mode(table, store, true)
    }

    /// Tiered cache: wraps the (stable) device table in a
    /// [`TieredMap`] and arms the cooldown freeze knob. After a
    /// [`GpuCache::cooldown`], the surviving residents live in an
    /// immutable perfect-hash tier — one-probe hits at load factor
    /// ~1.0 — while fresh admissions land in the mutable tier and a
    /// write to a frozen key promotes it back out. Growth mode is
    /// inherited from the wrapped table (`can_grow`). Returns `None`
    /// for unstable tables, as [`GpuCache::new`] does.
    pub fn with_tiered(table: Arc<dyn ConcurrentMap>, store: HostStore) -> Option<Self> {
        if !table.is_stable() {
            return None;
        }
        let grow = table.can_grow();
        let mut cache = Self::with_mode(Arc::new(TieredMap::new(table)), store, grow)?;
        cache.freeze_on_cooldown = true;
        Some(cache)
    }

    fn with_mode(table: Arc<dyn ConcurrentMap>, store: HostStore, grow: bool) -> Option<Self> {
        if !table.is_stable() {
            return None;
        }
        let ring_cap = ((table.capacity() as f64) * RING_FRACTION) as usize;
        Some(Self {
            table,
            store,
            ring: VecDeque::with_capacity(ring_cap + 1),
            ring_cap: ring_cap.max(1),
            grow,
            freeze_on_cooldown: false,
            hits: 0,
            misses: 0,
            evictions: 0,
        })
    }

    /// Current admission bound: fixed at construction normally, tracking
    /// the LIVE capacity in growth mode — up through growths, and back
    /// down when a cool-down compaction shrinks the device table.
    fn live_ring_cap(&mut self) -> usize {
        if self.grow {
            let cap = ((self.table.capacity() as f64) * RING_FRACTION) as usize;
            self.ring_cap = cap.max(1);
        }
        self.ring_cap
    }

    /// Cool-down path for the growth-mode cache: when the hot set
    /// contracts, holding peak capacity wastes device memory — the
    /// inverse of the grow-instead-of-evict admission policy. Evicts
    /// FIFO down to `target_resident` keys (they "return to the CPU";
    /// the host store already holds them), then asks the device table
    /// to compact itself — chained ½× shrinks down to its provisioning
    /// or the occupancy guard — and lets the admission ring follow the
    /// compacted capacity. Returns the number of keys evicted. On a
    /// fixed-capacity cache only the eviction happens (`request_shrink`
    /// refuses).
    pub fn cooldown(&mut self, target_resident: usize) -> usize {
        let mut evict: Vec<u64> = Vec::new();
        while self.ring.len() > target_resident {
            match self.ring.pop_front() {
                Some(old) => evict.push(old),
                None => break,
            }
        }
        if !evict.is_empty() {
            let mut eres = Vec::with_capacity(evict.len());
            self.table.erase_bulk(&evict, &mut eres);
            self.evictions += evict.len() as u64;
        }
        // Settle any in-flight migration first, then walk the capacity
        // down while the table still accepts halvings.
        self.table.quiesce_migration();
        while self.table.request_shrink() {
            self.table.quiesce_migration();
        }
        // Tiered caches end the cooldown by freezing the survivors: the
        // post-cooldown population is by construction the cold, rarely
        // written set, which is exactly what the perfect-hash tier is
        // for. (&mut self means no concurrent writer, satisfying
        // request_freeze's quiesced-writer contract.)
        if self.freeze_on_cooldown && self.table.can_freeze() {
            self.table.request_freeze();
        }
        if self.grow {
            self.ring_cap = (((self.table.capacity() as f64) * RING_FRACTION) as usize).max(1);
        }
        evict.len()
    }

    /// One cache access: query the device table; on miss fetch from the
    /// host store, insert, and evict FIFO if over capacity.
    pub fn get(&mut self, key: u64) -> Option<u64> {
        if let Some(v) = self.table.query(key) {
            self.hits += 1;
            return Some(v);
        }
        self.misses += 1;
        let v = self.store.fetch(key)?;
        // Fused insert (stable tables need no lock to later read/modify
        // the value in place).
        match self.table.upsert(key, v, &UpsertOp::InsertIfUnique) {
            UpsertResult::Inserted => {
                self.ring.push_back(key);
                if self.ring.len() > self.live_ring_cap() {
                    if let Some(old) = self.ring.pop_front() {
                        // Evicted keys "are returned to the CPU" — the
                        // store already holds them; just drop from device.
                        self.table.erase(old);
                        self.evictions += 1;
                    }
                }
            }
            UpsertResult::Updated => { /* raced with ourselves: fine */ }
            UpsertResult::Full => {
                // Fixed table saturated (can happen transiently right at
                // the ring boundary): evict eagerly and retry once. A
                // growable table only reports Full at its policy ceiling,
                // where eviction is the correct fallback too.
                if let Some(old) = self.ring.pop_front() {
                    self.table.erase(old);
                    self.evictions += 1;
                    if self.table.upsert(key, v, &UpsertOp::InsertIfUnique)
                        == UpsertResult::Inserted
                    {
                        self.ring.push_back(key);
                    }
                }
            }
        }
        Some(v)
    }

    /// Bulk cache access — the batch-native hot path: ONE `query_bulk`
    /// over the device table answers the whole batch; misses fetch from
    /// the host store and install via ONE `upsert_bulk`, with FIFO
    /// evictions batched through `erase_bulk`. Appends one result per
    /// key to `out` in input order.
    ///
    /// Semantics match a loop of [`GpuCache::get`] except for two batch
    /// artifacts: a key missing twice *within* one batch counts every
    /// occurrence as a miss (the device query phase runs before the
    /// install phase, as it would across two GPU kernel launches), and
    /// residency may transiently exceed the ring cap mid-batch before the
    /// eviction phase restores it.
    pub fn get_many(&mut self, keys: &[u64], out: &mut Vec<Option<u64>>) {
        let base = out.len();
        self.table.query_bulk(keys, out);
        let mut miss_pairs: Vec<(u64, u64)> = Vec::new();
        for (i, &k) in keys.iter().enumerate() {
            match out[base + i] {
                Some(_) => self.hits += 1,
                None => {
                    self.misses += 1;
                    if let Some(v) = self.store.fetch(k) {
                        out[base + i] = Some(v);
                        miss_pairs.push((k, v));
                    }
                }
            }
        }
        if miss_pairs.is_empty() {
            return;
        }
        let mut ins = Vec::with_capacity(miss_pairs.len());
        self.table
            .upsert_bulk(&miss_pairs, &UpsertOp::InsertIfUnique, &mut ins);
        let mut evict: Vec<u64> = Vec::new();
        for (j, r) in ins.iter().enumerate() {
            let (k, v) = miss_pairs[j];
            match r {
                UpsertResult::Inserted => self.ring.push_back(k),
                UpsertResult::Updated => { /* in-batch duplicate: resident */ }
                UpsertResult::Full => {
                    // Bulk results were computed before any retries, so
                    // an in-batch duplicate of a key an earlier Full arm
                    // already installed also reports Full — re-check
                    // before evicting an innocent resident for nothing.
                    if self.table.query(k).is_some() {
                        continue;
                    }
                    // Device table saturated mid-batch: evict eagerly and
                    // retry once (the scalar path's discipline).
                    if let Some(old) = self.ring.pop_front() {
                        self.table.erase(old);
                        self.evictions += 1;
                        if self.table.upsert(k, v, &UpsertOp::InsertIfUnique)
                            == UpsertResult::Inserted
                        {
                            self.ring.push_back(k);
                        }
                    }
                }
            }
            while self.ring.len() > self.live_ring_cap() {
                if let Some(old) = self.ring.pop_front() {
                    evict.push(old);
                }
            }
        }
        if !evict.is_empty() {
            let mut eres = Vec::with_capacity(evict.len());
            self.table.erase_bulk(&evict, &mut eres);
            self.evictions += evict.len() as u64;
        }
    }

    pub fn resident(&self) -> usize {
        self.ring.len()
    }

    /// Residents currently served from the frozen read-optimized tier
    /// (0 for untiered caches).
    pub fn frozen_resident(&self) -> usize {
        self.table.frozen_len()
    }

    pub fn hit_rate(&self) -> f64 {
        if self.hits + self.misses == 0 {
            0.0
        } else {
            self.hits as f64 / (self.hits + self.misses) as f64
        }
    }

    /// Device footprint (for the paper's chaining-growth observation).
    pub fn device_bytes(&self) -> usize {
        self.table.device_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tables::{build_table, TableKind};
    use crate::workloads::keys::{distinct_keys, UniverseDraws};

    fn store_of(keys: &[u64]) -> HostStore {
        HostStore::new(keys.iter().map(|&k| (k, k ^ 0xCAFE)))
    }

    #[test]
    fn cache_returns_correct_values() {
        let data = distinct_keys(2000, 0xCA);
        let t = build_table(TableKind::P2Meta, 512);
        let mut c = GpuCache::new(t, store_of(&data)).unwrap();
        let mut draws = UniverseDraws::new(&data, 1);
        for _ in 0..10_000 {
            let k = draws.next_key();
            assert_eq!(c.get(k), Some(k ^ 0xCAFE));
        }
        assert!(c.hits > 0 && c.misses > 0 && c.evictions > 0);
    }

    #[test]
    fn load_factor_never_exceeds_85_percent() {
        let data = distinct_keys(4000, 0xCB);
        let t = build_table(TableKind::Double, 512);
        let cap = t.capacity();
        let mut c = GpuCache::new(std::sync::Arc::clone(&t), store_of(&data)).unwrap();
        let mut draws = UniverseDraws::new(&data, 2);
        for _ in 0..20_000 {
            let k = draws.next_key();
            c.get(k);
            assert!(t.len() <= (cap as f64 * 0.86) as usize, "lf exceeded");
        }
    }

    #[test]
    fn get_many_matches_scalar_semantics() {
        let data = distinct_keys(2000, 0xCE);
        let t = build_table(TableKind::DoubleMeta, 512);
        let mut c = GpuCache::new(t, store_of(&data)).unwrap();
        let mut draws = UniverseDraws::new(&data, 4);
        let mut out = Vec::new();
        for _ in 0..40 {
            let batch: Vec<u64> = (0..256).map(|_| draws.next_key()).collect();
            out.clear();
            c.get_many(&batch, &mut out);
            assert_eq!(out.len(), batch.len());
            for (k, r) in batch.iter().zip(&out) {
                assert_eq!(*r, Some(k ^ 0xCAFE), "wrong cached value");
            }
            // Eviction phase restores the ring cap after every batch.
            assert!(c.resident() <= (c.table.capacity() as f64 * 0.85) as usize + 1);
        }
        assert!(c.hits > 0 && c.misses > 0 && c.evictions > 0);
        // Unknown keys still miss.
        out.clear();
        c.get_many(&[0xDEAD_0000_0000_0001], &mut out);
        assert_eq!(out[0], None);
    }

    #[test]
    fn unknown_keys_return_none() {
        let data = distinct_keys(100, 0xCC);
        let t = build_table(TableKind::Iceberg, 256);
        let mut c = GpuCache::new(t, store_of(&data)).unwrap();
        assert_eq!(c.get(0xDEAD_0000_0000_0001), None);
    }

    #[test]
    fn cuckoo_cannot_run_caching() {
        let t = build_table(TableKind::Cuckoo, 256);
        assert!(
            GpuCache::new(t, HostStore::new(std::iter::empty())).is_none(),
            "unstable tables must be rejected (paper §6.6)"
        );
    }

    #[test]
    fn growth_mode_requires_a_growable_table() {
        let fixed = build_table(TableKind::Chaining, 256);
        assert!(
            GpuCache::with_growth(fixed, HostStore::new(std::iter::empty())).is_none(),
            "fixed tables cannot run the growth-mode cache"
        );
    }

    #[test]
    fn growth_mode_admits_past_nominal_without_eviction() {
        use crate::tables::{GrowableMap, GrowthPolicy, TableConfig};
        let data = distinct_keys(2000, 0xCF);
        let t = std::sync::Arc::new(GrowableMap::new(
            TableKind::Chaining,
            TableConfig::for_kind(TableKind::Chaining, 512),
            GrowthPolicy {
                migration_batch: 16,
                ..Default::default()
            },
        ));
        let nominal = t.capacity();
        let mut c =
            GpuCache::with_growth(std::sync::Arc::clone(&t) as _, store_of(&data)).unwrap();
        let mut draws = UniverseDraws::new(&data, 5);
        for _ in 0..20_000 {
            let k = draws.next_key();
            assert_eq!(c.get(k), Some(k ^ 0xCAFE));
        }
        assert!(t.quiesce_migration());
        assert_eq!(c.evictions, 0, "growth replaces eviction");
        assert!(
            c.resident() > nominal,
            "cache never outgrew its nominal table: {} <= {nominal}",
            c.resident()
        );
        assert!(t.grow_events() >= 1, "the device table never grew");
        // With the whole dataset eventually resident, hits dominate.
        c.hits = 0;
        c.misses = 0;
        for _ in 0..4_000 {
            c.get(draws.next_key());
        }
        assert!(c.hit_rate() > 0.95, "hit rate {} after full admission", c.hit_rate());
    }

    #[test]
    fn cooldown_compacts_the_device_table_back_to_nominal() {
        use crate::tables::{GrowableMap, GrowthPolicy, TableConfig};
        // Heat a 512-slot growable chaining cache with a 4000-key hot
        // set (grows ~8×), then cool: the FIFO evicts down and chained
        // compactions must walk the device footprint back to the
        // provisioning — the fix for chaining's never-unlinked-node
        // growth, which erases alone cannot reclaim.
        let data = distinct_keys(4000, 0xD0);
        let t = std::sync::Arc::new(GrowableMap::new(
            TableKind::Chaining,
            TableConfig::for_kind(TableKind::Chaining, 512),
            GrowthPolicy {
                migration_batch: 16,
                ..Default::default()
            },
        ));
        let nominal_cap = t.capacity();
        let mut c =
            GpuCache::with_growth(std::sync::Arc::clone(&t) as _, store_of(&data)).unwrap();
        let mut draws = UniverseDraws::new(&data, 6);
        for _ in 0..30_000 {
            let k = draws.next_key();
            assert_eq!(c.get(k), Some(k ^ 0xCAFE));
        }
        assert!(t.quiesce_migration());
        assert!(t.capacity() >= nominal_cap * 4, "heat phase never grew the table");
        let peak_bytes = c.device_bytes();
        let evicted = c.cooldown(100);
        assert!(evicted > 0, "cooldown below residency must evict");
        assert!(t.shrink_events() >= 1, "cooldown never compacted");
        assert_eq!(t.capacity(), nominal_cap, "capacity never returned to nominal");
        assert!(
            c.device_bytes() * 4 < peak_bytes,
            "footprint {} never returned toward nominal from peak {peak_bytes}",
            c.device_bytes()
        );
        assert!(c.resident() <= 100);
        // The cooled cache still serves correctly, with the ring bound
        // following the compacted capacity (admissions evict again).
        let hot: Vec<u64> = data.iter().copied().take(200).collect();
        let mut hot_draws = UniverseDraws::new(&hot, 7);
        for _ in 0..2_000 {
            let k = hot_draws.next_key();
            assert_eq!(c.get(k), Some(k ^ 0xCAFE));
            assert!(
                c.resident() <= (t.capacity() as f64 * 0.85) as usize + 1,
                "ring cap did not follow the compacted capacity"
            );
        }
    }

    #[test]
    fn tiered_cooldown_freezes_surviving_residents() {
        // Warm a tiered cache, cool it down: the FIFO survivors must
        // land in the frozen tier and keep serving hits, while fresh
        // admissions go to the mutable tier and a frozen-key write
        // promotes back out — all through the unchanged GpuCache API.
        let data = distinct_keys(2000, 0xD1);
        let t = build_table(TableKind::P2Meta, 1024);
        let mut c = GpuCache::with_tiered(t, store_of(&data)).unwrap();
        let hot: Vec<u64> = data.iter().copied().take(400).collect();
        for &k in &hot {
            assert_eq!(c.get(k), Some(k ^ 0xCAFE));
        }
        assert_eq!(c.resident(), 400);
        assert_eq!(c.frozen_resident(), 0, "nothing frozen before cooldown");
        let evicted = c.cooldown(256);
        assert_eq!(evicted, 400 - 256);
        assert_eq!(c.frozen_resident(), 256, "cooldown must freeze the survivors");
        // FIFO evicts from the front: the survivors are the last 256
        // admitted, and they now hit without touching the host store.
        c.hits = 0;
        c.misses = 0;
        for &k in &hot[400 - 256..] {
            assert_eq!(c.get(k), Some(k ^ 0xCAFE));
        }
        assert_eq!(c.misses, 0, "frozen residents must still hit");
        assert_eq!(c.frozen_resident(), 256, "reads must not promote");
        // Evicted keys really left the device: they miss and re-admit
        // into the mutable tier (the frozen tier is immutable).
        for &k in &hot[..64] {
            assert_eq!(c.get(k), Some(k ^ 0xCAFE));
        }
        assert_eq!(c.misses, 64);
        assert_eq!(c.frozen_resident(), 256);
        assert_eq!(c.resident(), 256 + 64);
        // A second cooldown re-freezes the merged population.
        c.cooldown(c.resident());
        assert_eq!(c.frozen_resident(), 256 + 64, "refreeze must absorb new admissions");
    }

    #[test]
    fn hit_rate_tracks_cache_ratio() {
        // Cache sized at ~50% of data + uniform queries → hit rate well
        // above 25% and below 95% once warm.
        let data = distinct_keys(1000, 0xCD);
        let t = build_table(TableKind::P2, 512);
        let mut c = GpuCache::new(t, store_of(&data)).unwrap();
        let mut draws = UniverseDraws::new(&data, 3);
        for _ in 0..2000 {
            c.get(draws.next_key());
        }
        c.hits = 0;
        c.misses = 0;
        for _ in 0..10_000 {
            c.get(draws.next_key());
        }
        let hr = c.hit_rate();
        assert!((0.25..0.95).contains(&hr), "hit rate {hr}");
    }
}
