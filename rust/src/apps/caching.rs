//! GPU caching workload (paper §6.6, Figure 6.3).
//!
//! "The hash table resides on the GPU, while a key-value buffer remains on
//! the CPU. Queries first check the GPU hash table; if a key is missing,
//! it is retrieved from the CPU and inserted into the GPU, evicting an
//! entry in FIFO order if necessary. ... A ring queue, set to 85% of the
//! hash table size, ensures the table's maximum load factor never exceeds
//! 85%."
//!
//! The design exploits *stability*: the hot path is a fused
//! query-or-insert with in-place value access and no table-wide locking.
//! CuckooHT is not stable and "is unable to run this benchmark" — we
//! enforce the same restriction via [`ConcurrentMap::is_stable`].
//!
//! FIFO (the paper's quoted baseline) is now one of three eviction
//! policies ([`EvictionPolicy`]): caches built over lifecycle-armed
//! tables can instead admit entries with a TTL and reclaim expired
//! residents before any live one is evicted (`Ttl`), or additionally
//! rank the oldest residents by the frequency counter the table's own
//! tag probes maintain and evict the coldest (`TtlFrequency`, the
//! segcache-style policy) — hot old entries survive, cold ones leave,
//! at zero extra cost on the hit path. `bench aging`'s eviction-policy
//! appendix measures the three head-to-head under zipfian churn.

use std::collections::VecDeque;
use std::sync::Arc;

use crate::tables::{ConcurrentMap, TieredMap, UpsertOp, UpsertResult};

/// Fraction of table capacity the admission ring may occupy (paper
/// §6.6; applies to every eviction policy).
const RING_FRACTION: f64 = 0.85;

/// Oldest residents examined per eviction under the TTL/frequency
/// policies — a bounded ring-front sample, so victim choice costs O(1)
/// probes instead of a table scan (segcache's merge window, shrunk to
/// the testbed's scale).
const VICTIM_SAMPLE: usize = 8;

/// How [`GpuCache`] chooses a victim when residency exceeds the ring
/// cap. The non-FIFO policies need a device table built with
/// [`crate::tables::LifecycleConfig`] metadata (entry TTL + frequency
/// counters packed next to the fingerprint bytes).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum EvictionPolicy {
    /// The paper's §6.6 baseline: evict the oldest admission.
    #[default]
    Fifo,
    /// Admissions carry a TTL; an expired resident in the ring-front
    /// sample is reclaimed before any live entry, falling back to the
    /// oldest admission when nothing has expired.
    Ttl,
    /// TTL first, then lowest frequency within the ring-front sample
    /// (ties go to the oldest) — the segcache-style policy: reads bump
    /// the per-entry counter for free on the tag probe, so a hot old
    /// resident outlives a cold newer one.
    TtlFrequency,
}

/// Host-side backing store: the full dataset (simulating CPU DRAM).
pub struct HostStore {
    map: std::collections::HashMap<u64, u64>,
}

impl HostStore {
    pub fn new(pairs: impl IntoIterator<Item = (u64, u64)>) -> Self {
        Self {
            map: pairs.into_iter().collect(),
        }
    }

    #[inline]
    pub fn fetch(&self, key: u64) -> Option<u64> {
        self.map.get(&key).copied()
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// Cache of a [`HostStore`] in a device hash table — FIFO by default,
/// TTL/frequency-aware via [`GpuCache::with_policy`].
pub struct GpuCache {
    table: Arc<dyn ConcurrentMap>,
    store: HostStore,
    /// Admission ring of resident keys in arrival order, capped at 85%
    /// of table capacity (recomputed from the live capacity in growth
    /// mode). FIFO evicts its front; the TTL/frequency policies pick a
    /// victim from its front [`VICTIM_SAMPLE`].
    ring: VecDeque<u64>,
    ring_cap: usize,
    /// Victim-selection policy; non-FIFO requires lifecycle metadata.
    policy: EvictionPolicy,
    /// Deadline (clock ticks) each non-FIFO admission is armed with.
    admit_ttl: u64,
    /// Growth mode: the device table grows online instead of evicting —
    /// the ring cap follows the grown capacity, so saturation triggers
    /// a 2× growth rather than the Full-eviction-retry contortion.
    grow: bool,
    /// Freeze knob ([`GpuCache::with_tiered`]): cooldown ends by
    /// snapshotting the surviving residents into the device table's
    /// frozen read-optimized tier, so the post-cooldown steady state
    /// serves its (cold, read-mostly) hits at ~1 probe/op.
    freeze_on_cooldown: bool,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Evictions that reclaimed an already-expired resident (subset of
    /// `evictions`; only the TTL/frequency policies ever count here).
    pub expired_evictions: u64,
}

impl GpuCache {
    /// Returns `None` when the table design cannot run this workload
    /// (unstable tables — the paper's CuckooHT case).
    pub fn new(table: Arc<dyn ConcurrentMap>, store: HostStore) -> Option<Self> {
        Self::with_mode(table, store, false)
    }

    /// Growth-mode cache over a growable table
    /// ([`crate::tables::GrowableMap`]): instead of FIFO-evicting at 85%
    /// of a fixed capacity, the device table grows 2× online and keeps
    /// admitting — the paper's §6.6 chaining observation (a 10% cache
    /// growing toward 28% of the dataset) reproduced through real growth
    /// rather than Full-driven eviction churn. Returns `None` for
    /// unstable or fixed-capacity tables.
    pub fn with_growth(table: Arc<dyn ConcurrentMap>, store: HostStore) -> Option<Self> {
        if !table.can_grow() {
            return None;
        }
        Self::with_mode(table, store, true)
    }

    /// Tiered cache: wraps the (stable) device table in a
    /// [`TieredMap`] and arms the cooldown freeze knob. After a
    /// [`GpuCache::cooldown`], the surviving residents live in an
    /// immutable perfect-hash tier — one-probe hits at load factor
    /// ~1.0 — while fresh admissions land in the mutable tier and a
    /// write to a frozen key promotes it back out. Growth mode is
    /// inherited from the wrapped table (`can_grow`). Returns `None`
    /// for unstable tables, as [`GpuCache::new`] does.
    pub fn with_tiered(table: Arc<dyn ConcurrentMap>, store: HostStore) -> Option<Self> {
        if !table.is_stable() {
            return None;
        }
        let grow = table.can_grow();
        let mut cache = Self::with_mode(Arc::new(TieredMap::new(table)), store, grow)?;
        cache.freeze_on_cooldown = true;
        Some(cache)
    }

    /// Policy cache: like [`GpuCache::new`] but with an explicit
    /// [`EvictionPolicy`]. Non-FIFO admissions are armed with a TTL of
    /// `admit_ttl` clock ticks against the table's lifecycle clock (a
    /// TTL beyond the deadline-ring horizon stores immortal, leaving
    /// pure frequency ranking). Returns `None` for unstable tables, or
    /// when a TTL/frequency policy is requested on a table built
    /// without lifecycle metadata.
    pub fn with_policy(
        table: Arc<dyn ConcurrentMap>,
        store: HostStore,
        policy: EvictionPolicy,
        admit_ttl: u64,
    ) -> Option<Self> {
        if policy != EvictionPolicy::Fifo && !table.supports_ttl() {
            return None;
        }
        let mut cache = Self::with_mode(table, store, false)?;
        cache.policy = policy;
        cache.admit_ttl = admit_ttl;
        Some(cache)
    }

    fn with_mode(table: Arc<dyn ConcurrentMap>, store: HostStore, grow: bool) -> Option<Self> {
        if !table.is_stable() {
            return None;
        }
        let ring_cap = ((table.capacity() as f64) * RING_FRACTION) as usize;
        Some(Self {
            table,
            store,
            ring: VecDeque::with_capacity(ring_cap + 1),
            ring_cap: ring_cap.max(1),
            policy: EvictionPolicy::Fifo,
            admit_ttl: 0,
            grow,
            freeze_on_cooldown: false,
            hits: 0,
            misses: 0,
            evictions: 0,
            expired_evictions: 0,
        })
    }

    /// Install one admission, armed with the policy's TTL when the
    /// policy uses one.
    fn admit(&self, key: u64, val: u64) -> UpsertResult {
        match self.policy {
            EvictionPolicy::Fifo => self.table.upsert(key, val, &UpsertOp::InsertIfUnique),
            _ => self
                .table
                .upsert_ttl(key, val, self.admit_ttl, &UpsertOp::InsertIfUnique),
        }
    }

    /// Ring index of the next victim under the active policy. FIFO is
    /// always the front; the TTL/frequency policies scan the front
    /// [`VICTIM_SAMPLE`] — an expired resident wins outright (its slot
    /// is already dead), otherwise `TtlFrequency` takes the lowest
    /// frequency counter, oldest on ties.
    fn pick_victim(&self) -> usize {
        match self.policy {
            EvictionPolicy::Fifo => 0,
            EvictionPolicy::Ttl => self
                .ring
                .iter()
                .take(VICTIM_SAMPLE)
                .position(|&k| self.table.entry_frequency(k).is_none())
                .unwrap_or(0),
            EvictionPolicy::TtlFrequency => {
                let mut best = 0usize;
                let mut best_freq = u8::MAX;
                for (i, &k) in self.ring.iter().take(VICTIM_SAMPLE).enumerate() {
                    match self.table.entry_frequency(k) {
                        // Expired (or concurrently removed): free win.
                        None => return i,
                        Some(f) if f < best_freq => {
                            best_freq = f;
                            best = i;
                        }
                        Some(_) => {}
                    }
                }
                best
            }
        }
    }

    /// Account one successful admission in the ring. Under a TTL policy
    /// an admission can revive an expired resident's corpse in place
    /// (`upsert_ttl` over the corpse reports `Inserted`): such a key is
    /// still in the ring and must keep its one slot — pushing again
    /// would double-count residency and let a later eviction of the
    /// stale slot erase the revived live entry.
    fn ring_push(&mut self, key: u64) {
        if self.policy != EvictionPolicy::Fifo && self.ring.contains(&key) {
            return;
        }
        self.ring.push_back(key);
    }

    /// Drop one resident chosen by the eviction policy — removes it
    /// from the ring, erases its device copy, counts the eviction (and
    /// whether it was an expiry reclaim). Returns the evicted key.
    fn evict_one(&mut self) -> Option<u64> {
        let idx = self.pick_victim();
        let old = self.ring.remove(idx)?;
        if self.policy != EvictionPolicy::Fifo && self.table.entry_frequency(old).is_none() {
            self.expired_evictions += 1;
        }
        self.table.erase(old);
        self.evictions += 1;
        Some(old)
    }

    /// Current admission bound: fixed at construction normally, tracking
    /// the LIVE capacity in growth mode — up through growths, and back
    /// down when a cool-down compaction shrinks the device table.
    fn live_ring_cap(&mut self) -> usize {
        if self.grow {
            let cap = ((self.table.capacity() as f64) * RING_FRACTION) as usize;
            self.ring_cap = cap.max(1);
        }
        self.ring_cap
    }

    /// Cool-down path for the growth-mode cache: when the hot set
    /// contracts, holding peak capacity wastes device memory — the
    /// inverse of the grow-instead-of-evict admission policy. Evicts
    /// FIFO down to `target_resident` keys (they "return to the CPU";
    /// the host store already holds them), then asks the device table
    /// to compact itself — chained ½× shrinks down to its provisioning
    /// or the occupancy guard — and lets the admission ring follow the
    /// compacted capacity. Returns the number of keys evicted. On a
    /// fixed-capacity cache only the eviction happens (`request_shrink`
    /// refuses).
    pub fn cooldown(&mut self, target_resident: usize) -> usize {
        let mut evict: Vec<u64> = Vec::new();
        while self.ring.len() > target_resident {
            match self.ring.pop_front() {
                Some(old) => evict.push(old),
                None => break,
            }
        }
        if !evict.is_empty() {
            let mut eres = Vec::with_capacity(evict.len());
            self.table.erase_bulk(&evict, &mut eres);
            self.evictions += evict.len() as u64;
        }
        // Settle any in-flight migration first, then walk the capacity
        // down while the table still accepts halvings.
        self.table.quiesce_migration();
        while self.table.request_shrink() {
            self.table.quiesce_migration();
        }
        // Tiered caches end the cooldown by freezing the survivors: the
        // post-cooldown population is by construction the cold, rarely
        // written set, which is exactly what the perfect-hash tier is
        // for. (&mut self means no concurrent writer, satisfying
        // request_freeze's quiesced-writer contract.)
        if self.freeze_on_cooldown && self.table.can_freeze() {
            self.table.request_freeze();
        }
        if self.grow {
            self.ring_cap = (((self.table.capacity() as f64) * RING_FRACTION) as usize).max(1);
        }
        evict.len()
    }

    /// One cache access: query the device table; on miss fetch from the
    /// host store, insert, and evict FIFO if over capacity.
    pub fn get(&mut self, key: u64) -> Option<u64> {
        if let Some(v) = self.table.query(key) {
            self.hits += 1;
            return Some(v);
        }
        self.misses += 1;
        let v = self.store.fetch(key)?;
        // Fused insert (stable tables need no lock to later read/modify
        // the value in place). An admission over an expired resident's
        // corpse revives the slot in place and reports Inserted;
        // `ring_push` keeps the revived key's existing ring position.
        match self.admit(key, v) {
            UpsertResult::Inserted => {
                self.ring_push(key);
                if self.ring.len() > self.live_ring_cap() {
                    // Evicted keys "are returned to the CPU" — the
                    // store already holds them; just drop from device.
                    self.evict_one();
                }
            }
            UpsertResult::Updated => { /* raced with ourselves: fine */ }
            UpsertResult::Full => {
                // Fixed table saturated (can happen transiently right at
                // the ring boundary): evict eagerly and retry once. A
                // growable table only reports Full at its policy ceiling,
                // where eviction is the correct fallback too.
                if self.evict_one().is_some()
                    && self.admit(key, v) == UpsertResult::Inserted
                {
                    self.ring_push(key);
                }
            }
        }
        Some(v)
    }

    /// Bulk cache access — the batch-native hot path: ONE `query_bulk`
    /// over the device table answers the whole batch; misses fetch from
    /// the host store and install via ONE `upsert_bulk`, with FIFO
    /// evictions batched through `erase_bulk`. Appends one result per
    /// key to `out` in input order.
    ///
    /// Semantics match a loop of [`GpuCache::get`] except for two batch
    /// artifacts: a key missing twice *within* one batch counts every
    /// occurrence as a miss (the device query phase runs before the
    /// install phase, as it would across two GPU kernel launches), and
    /// residency may transiently exceed the ring cap mid-batch before the
    /// eviction phase restores it.
    pub fn get_many(&mut self, keys: &[u64], out: &mut Vec<Option<u64>>) {
        let base = out.len();
        self.table.query_bulk(keys, out);
        let mut miss_pairs: Vec<(u64, u64)> = Vec::new();
        for (i, &k) in keys.iter().enumerate() {
            match out[base + i] {
                Some(_) => self.hits += 1,
                None => {
                    self.misses += 1;
                    if let Some(v) = self.store.fetch(k) {
                        out[base + i] = Some(v);
                        miss_pairs.push((k, v));
                    }
                }
            }
        }
        if miss_pairs.is_empty() {
            return;
        }
        let mut ins = Vec::with_capacity(miss_pairs.len());
        if self.policy == EvictionPolicy::Fifo {
            self.table
                .upsert_bulk(&miss_pairs, &UpsertOp::InsertIfUnique, &mut ins);
        } else {
            // TTL admissions carry per-entry deadlines the bulk upsert
            // API has no slot for; install the (already rare, by
            // definition of a miss) batch scalar-wise instead.
            for &(k, v) in &miss_pairs {
                ins.push(self.admit(k, v));
            }
        }
        let mut evict: Vec<u64> = Vec::new();
        for (j, r) in ins.iter().enumerate() {
            let (k, v) = miss_pairs[j];
            match r {
                UpsertResult::Inserted => self.ring_push(k),
                UpsertResult::Updated => { /* in-batch duplicate: resident */ }
                UpsertResult::Full => {
                    // Bulk results were computed before any retries, so
                    // an in-batch duplicate of a key an earlier Full arm
                    // already installed also reports Full — re-check
                    // before evicting an innocent resident for nothing.
                    if self.table.query(k).is_some() {
                        continue;
                    }
                    // Device table saturated mid-batch: evict eagerly and
                    // retry once (the scalar path's discipline).
                    if self.evict_one().is_some()
                        && self.admit(k, v) == UpsertResult::Inserted
                    {
                        self.ring_push(k);
                    }
                }
            }
            while self.ring.len() > self.live_ring_cap() {
                match self.policy {
                    // FIFO victims batch into one erase_bulk below.
                    EvictionPolicy::Fifo => match self.ring.pop_front() {
                        Some(old) => evict.push(old),
                        None => break,
                    },
                    _ => {
                        if self.evict_one().is_none() {
                            break;
                        }
                    }
                }
            }
        }
        if !evict.is_empty() {
            let mut eres = Vec::with_capacity(evict.len());
            self.table.erase_bulk(&evict, &mut eres);
            self.evictions += evict.len() as u64;
        }
    }

    pub fn resident(&self) -> usize {
        self.ring.len()
    }

    /// Residents currently served from the frozen read-optimized tier
    /// (0 for untiered caches).
    #[cfg(test)] // test-only surface (warpspeed-analyze WS3)
    pub fn frozen_resident(&self) -> usize {
        self.table.frozen_len()
    }

    pub fn hit_rate(&self) -> f64 {
        if self.hits + self.misses == 0 {
            0.0
        } else {
            self.hits as f64 / (self.hits + self.misses) as f64
        }
    }

    /// Device footprint (for the paper's chaining-growth observation).
    pub fn device_bytes(&self) -> usize {
        self.table.device_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tables::{build_table, TableKind};
    use crate::workloads::keys::{distinct_keys, UniverseDraws};

    fn store_of(keys: &[u64]) -> HostStore {
        HostStore::new(keys.iter().map(|&k| (k, k ^ 0xCAFE)))
    }

    #[test]
    fn cache_returns_correct_values() {
        let data = distinct_keys(2000, 0xCA);
        let t = build_table(TableKind::P2Meta, 512);
        let mut c = GpuCache::new(t, store_of(&data)).unwrap();
        let mut draws = UniverseDraws::new(&data, 1);
        for _ in 0..10_000 {
            let k = draws.next_key();
            assert_eq!(c.get(k), Some(k ^ 0xCAFE));
        }
        assert!(c.hits > 0 && c.misses > 0 && c.evictions > 0);
    }

    #[test]
    fn load_factor_never_exceeds_85_percent() {
        let data = distinct_keys(4000, 0xCB);
        let t = build_table(TableKind::Double, 512);
        let cap = t.capacity();
        let mut c = GpuCache::new(std::sync::Arc::clone(&t), store_of(&data)).unwrap();
        let mut draws = UniverseDraws::new(&data, 2);
        for _ in 0..20_000 {
            let k = draws.next_key();
            c.get(k);
            assert!(t.len() <= (cap as f64 * 0.86) as usize, "lf exceeded");
        }
    }

    #[test]
    fn get_many_matches_scalar_semantics() {
        let data = distinct_keys(2000, 0xCE);
        let t = build_table(TableKind::DoubleMeta, 512);
        let mut c = GpuCache::new(t, store_of(&data)).unwrap();
        let mut draws = UniverseDraws::new(&data, 4);
        let mut out = Vec::new();
        for _ in 0..40 {
            let batch: Vec<u64> = (0..256).map(|_| draws.next_key()).collect();
            out.clear();
            c.get_many(&batch, &mut out);
            assert_eq!(out.len(), batch.len());
            for (k, r) in batch.iter().zip(&out) {
                assert_eq!(*r, Some(k ^ 0xCAFE), "wrong cached value");
            }
            // Eviction phase restores the ring cap after every batch.
            assert!(c.resident() <= (c.table.capacity() as f64 * 0.85) as usize + 1);
        }
        assert!(c.hits > 0 && c.misses > 0 && c.evictions > 0);
        // Unknown keys still miss.
        out.clear();
        c.get_many(&[0xDEAD_0000_0000_0001], &mut out);
        assert_eq!(out[0], None);
    }

    #[test]
    fn unknown_keys_return_none() {
        let data = distinct_keys(100, 0xCC);
        let t = build_table(TableKind::Iceberg, 256);
        let mut c = GpuCache::new(t, store_of(&data)).unwrap();
        assert_eq!(c.get(0xDEAD_0000_0000_0001), None);
    }

    #[test]
    fn cuckoo_cannot_run_caching() {
        let t = build_table(TableKind::Cuckoo, 256);
        assert!(
            GpuCache::new(t, HostStore::new(std::iter::empty())).is_none(),
            "unstable tables must be rejected (paper §6.6)"
        );
    }

    #[test]
    fn growth_mode_requires_a_growable_table() {
        let fixed = build_table(TableKind::Chaining, 256);
        assert!(
            GpuCache::with_growth(fixed, HostStore::new(std::iter::empty())).is_none(),
            "fixed tables cannot run the growth-mode cache"
        );
    }

    #[test]
    fn growth_mode_admits_past_nominal_without_eviction() {
        use crate::tables::{GrowableMap, GrowthPolicy, TableConfig};
        let data = distinct_keys(2000, 0xCF);
        let t = std::sync::Arc::new(GrowableMap::new(
            TableKind::Chaining,
            TableConfig::for_kind(TableKind::Chaining, 512),
            GrowthPolicy {
                migration_batch: 16,
                ..Default::default()
            },
        ));
        let nominal = t.capacity();
        let mut c =
            GpuCache::with_growth(std::sync::Arc::clone(&t) as _, store_of(&data)).unwrap();
        let mut draws = UniverseDraws::new(&data, 5);
        for _ in 0..20_000 {
            let k = draws.next_key();
            assert_eq!(c.get(k), Some(k ^ 0xCAFE));
        }
        assert!(t.quiesce_migration());
        assert_eq!(c.evictions, 0, "growth replaces eviction");
        assert!(
            c.resident() > nominal,
            "cache never outgrew its nominal table: {} <= {nominal}",
            c.resident()
        );
        assert!(t.grow_events() >= 1, "the device table never grew");
        // With the whole dataset eventually resident, hits dominate.
        c.hits = 0;
        c.misses = 0;
        for _ in 0..4_000 {
            c.get(draws.next_key());
        }
        assert!(c.hit_rate() > 0.95, "hit rate {} after full admission", c.hit_rate());
    }

    #[test]
    fn cooldown_compacts_the_device_table_back_to_nominal() {
        use crate::tables::{GrowableMap, GrowthPolicy, TableConfig};
        // Heat a 512-slot growable chaining cache with a 4000-key hot
        // set (grows ~8×), then cool: the FIFO evicts down and chained
        // compactions must walk the device footprint back to the
        // provisioning — the fix for chaining's never-unlinked-node
        // growth, which erases alone cannot reclaim.
        let data = distinct_keys(4000, 0xD0);
        let t = std::sync::Arc::new(GrowableMap::new(
            TableKind::Chaining,
            TableConfig::for_kind(TableKind::Chaining, 512),
            GrowthPolicy {
                migration_batch: 16,
                ..Default::default()
            },
        ));
        let nominal_cap = t.capacity();
        let mut c =
            GpuCache::with_growth(std::sync::Arc::clone(&t) as _, store_of(&data)).unwrap();
        let mut draws = UniverseDraws::new(&data, 6);
        for _ in 0..30_000 {
            let k = draws.next_key();
            assert_eq!(c.get(k), Some(k ^ 0xCAFE));
        }
        assert!(t.quiesce_migration());
        assert!(t.capacity() >= nominal_cap * 4, "heat phase never grew the table");
        let peak_bytes = c.device_bytes();
        let evicted = c.cooldown(100);
        assert!(evicted > 0, "cooldown below residency must evict");
        assert!(t.shrink_events() >= 1, "cooldown never compacted");
        assert_eq!(t.capacity(), nominal_cap, "capacity never returned to nominal");
        assert!(
            c.device_bytes() * 4 < peak_bytes,
            "footprint {} never returned toward nominal from peak {peak_bytes}",
            c.device_bytes()
        );
        assert!(c.resident() <= 100);
        // The cooled cache still serves correctly, with the ring bound
        // following the compacted capacity (admissions evict again).
        let hot: Vec<u64> = data.iter().copied().take(200).collect();
        let mut hot_draws = UniverseDraws::new(&hot, 7);
        for _ in 0..2_000 {
            let k = hot_draws.next_key();
            assert_eq!(c.get(k), Some(k ^ 0xCAFE));
            assert!(
                c.resident() <= (t.capacity() as f64 * 0.85) as usize + 1,
                "ring cap did not follow the compacted capacity"
            );
        }
    }

    #[test]
    fn tiered_cooldown_freezes_surviving_residents() {
        // Warm a tiered cache, cool it down: the FIFO survivors must
        // land in the frozen tier and keep serving hits, while fresh
        // admissions go to the mutable tier and a frozen-key write
        // promotes back out — all through the unchanged GpuCache API.
        let data = distinct_keys(2000, 0xD1);
        let t = build_table(TableKind::P2Meta, 1024);
        let mut c = GpuCache::with_tiered(t, store_of(&data)).unwrap();
        let hot: Vec<u64> = data.iter().copied().take(400).collect();
        for &k in &hot {
            assert_eq!(c.get(k), Some(k ^ 0xCAFE));
        }
        assert_eq!(c.resident(), 400);
        assert_eq!(c.frozen_resident(), 0, "nothing frozen before cooldown");
        let evicted = c.cooldown(256);
        assert_eq!(evicted, 400 - 256);
        assert_eq!(c.frozen_resident(), 256, "cooldown must freeze the survivors");
        // FIFO evicts from the front: the survivors are the last 256
        // admitted, and they now hit without touching the host store.
        c.hits = 0;
        c.misses = 0;
        for &k in &hot[400 - 256..] {
            assert_eq!(c.get(k), Some(k ^ 0xCAFE));
        }
        assert_eq!(c.misses, 0, "frozen residents must still hit");
        assert_eq!(c.frozen_resident(), 256, "reads must not promote");
        // Evicted keys really left the device: they miss and re-admit
        // into the mutable tier (the frozen tier is immutable).
        for &k in &hot[..64] {
            assert_eq!(c.get(k), Some(k ^ 0xCAFE));
        }
        assert_eq!(c.misses, 64);
        assert_eq!(c.frozen_resident(), 256);
        assert_eq!(c.resident(), 256 + 64);
        // A second cooldown re-freezes the merged population.
        c.cooldown(c.resident());
        assert_eq!(c.frozen_resident(), 256 + 64, "refreeze must absorb new admissions");
    }

    fn lifecycle_table(
        kind: TableKind,
        slots: usize,
        cfg: &crate::tables::LifecycleConfig,
    ) -> Arc<dyn ConcurrentMap> {
        crate::tables::build_table_with(
            kind,
            crate::tables::TableConfig::for_kind(kind, slots).with_lifecycle(cfg.clone()),
        )
    }

    #[test]
    fn ttl_policies_require_lifecycle_metadata() {
        use crate::tables::LifecycleConfig;
        let data = distinct_keys(100, 0xD2);
        let plain = build_table(TableKind::Double, 256);
        assert!(
            GpuCache::with_policy(plain, store_of(&data), EvictionPolicy::Ttl, 4).is_none(),
            "TTL policy on a lifecycle-less table must be refused"
        );
        let lc = LifecycleConfig::new(1);
        let t = lifecycle_table(TableKind::Double, 256, &lc);
        assert!(GpuCache::with_policy(
            t,
            store_of(&data),
            EvictionPolicy::TtlFrequency,
            4
        )
        .is_some());
        // FIFO never needs the metadata.
        let plain = build_table(TableKind::Double, 256);
        assert!(
            GpuCache::with_policy(plain, store_of(&data), EvictionPolicy::Fifo, 0).is_some()
        );
    }

    #[test]
    fn ttl_policy_reclaims_expired_residents_before_live_ones() {
        use crate::tables::LifecycleConfig;
        let lc = LifecycleConfig::new(1);
        let t = lifecycle_table(TableKind::DoubleMeta, 256, &lc);
        let cap = ((t.capacity() as f64) * 0.85) as usize;
        let data = distinct_keys(cap + 20, 0xD3);
        let mut c =
            GpuCache::with_policy(t, store_of(&data), EvictionPolicy::Ttl, 2).unwrap();
        let (mortal, fresh) = data.split_at(cap);
        for &k in mortal {
            assert_eq!(c.get(k), Some(k ^ 0xCAFE));
        }
        assert_eq!(c.resident(), cap);
        assert_eq!(c.evictions, 0);
        lc.clock.advance(3); // every resident is now a corpse
        for &k in fresh {
            assert_eq!(c.get(k), Some(k ^ 0xCAFE));
        }
        assert_eq!(
            c.expired_evictions, 20,
            "every eviction should have reclaimed an expired resident"
        );
        assert_eq!(c.evictions, 20);
        assert_eq!(c.resident(), cap);
        // The fresh admissions are live residents and hit.
        c.misses = 0;
        for &k in fresh {
            assert_eq!(c.get(k), Some(k ^ 0xCAFE));
        }
        assert_eq!(c.misses, 0, "a fresh admission was evicted over a corpse");
    }

    #[test]
    fn reviving_an_expired_resident_keeps_one_ring_slot() {
        use crate::tables::LifecycleConfig;
        let lc = LifecycleConfig::new(1);
        let t = lifecycle_table(TableKind::DoubleMeta, 256, &lc);
        let data = distinct_keys(8, 0xD5);
        let mut c =
            GpuCache::with_policy(Arc::clone(&t), store_of(&data), EvictionPolicy::Ttl, 2)
                .unwrap();
        for &k in &data {
            assert_eq!(c.get(k), Some(k ^ 0xCAFE));
        }
        assert_eq!(c.resident(), 8);
        lc.clock.advance(3); // every resident is a corpse now
        // Re-requesting a corpse misses, revives the entry in place, and
        // must NOT grow residency: the key already owns a ring slot.
        c.misses = 0;
        assert_eq!(c.get(data[3]), Some(data[3] ^ 0xCAFE));
        assert_eq!(c.misses, 1);
        assert_eq!(c.resident(), 8, "revival duplicated a ring slot");
        assert_eq!(c.evictions, 0);
        assert!(t.entry_frequency(data[3]).is_some(), "revived entry must be live");
    }

    #[test]
    fn frequency_policy_keeps_hot_old_entries_over_cold_ones() {
        use crate::tables::LifecycleConfig;
        let lc = LifecycleConfig::new(1);
        // DoubleMeta: the odd-stride probe walk covers every bucket, so
        // no admission below capacity can spuriously report `Full` and
        // perturb the exact eviction counts this test pins down.
        let t = lifecycle_table(TableKind::DoubleMeta, 256, &lc);
        let cap = ((t.capacity() as f64) * 0.85) as usize;
        let data = distinct_keys(cap + 1, 0xD4);
        // TTL far beyond the deadline-ring horizon → admissions store
        // immortal: pure frequency ranking, nothing ever expires.
        let mut c = GpuCache::with_policy(
            Arc::clone(&t),
            store_of(&data),
            EvictionPolicy::TtlFrequency,
            1_000_000,
        )
        .unwrap();
        for &k in &data[..cap] {
            assert_eq!(c.get(k), Some(k ^ 0xCAFE));
        }
        // Heat the OLDEST resident: each hit's tag probe bumps its
        // frequency counter for free.
        for _ in 0..5 {
            assert_eq!(c.get(data[0]), Some(data[0] ^ 0xCAFE));
        }
        assert!(t.entry_frequency(data[0]).unwrap_or(0) > 0);
        assert_eq!(t.entry_frequency(data[1]), Some(0));
        // One over-cap admission: the victim sample holds the hot
        // oldest entry and its cold neighbors — the cold one must go.
        assert_eq!(c.get(data[cap]), Some(data[cap] ^ 0xCAFE));
        assert_eq!(c.evictions, 1);
        assert_eq!(c.expired_evictions, 0, "nothing expired in this run");
        assert!(
            t.entry_frequency(data[0]).is_some(),
            "the hot old resident must survive FIFO order"
        );
        assert!(
            t.entry_frequency(data[1]).is_none(),
            "the cold old resident should have been the victim"
        );
    }

    #[test]
    fn hit_rate_tracks_cache_ratio() {
        // Cache sized at ~50% of data + uniform queries → hit rate well
        // above 25% and below 95% once warm.
        let data = distinct_keys(1000, 0xCD);
        let t = build_table(TableKind::P2, 512);
        let mut c = GpuCache::new(t, store_of(&data)).unwrap();
        let mut draws = UniverseDraws::new(&data, 3);
        for _ in 0..2000 {
            c.get(draws.next_key());
        }
        c.hits = 0;
        c.misses = 0;
        for _ in 0..10_000 {
            c.get(draws.next_key());
        }
        let hr = c.hit_rate();
        assert!((0.25..0.95).contains(&hr), "hit rate {hr}");
    }
}
