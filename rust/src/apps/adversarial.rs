//! Adversarial correctness benchmark (paper §4.1, Figure 4.1).
//!
//! "Keys are generated from a uniform-random distribution and mapped to
//! their primary buckets until every bucket in the table has exactly two
//! keys that map to it. The counterexample from Figure 4.1 is then
//! replayed in every bucket. If the hash table is correct, each bucket
//! should contain exactly one copy of the key."
//!
//! Two execution modes:
//!
//! * [`replay_concurrent`] — the paper's statistical mode: for each
//!   prepared bucket, three real threads race (T1+T2 insert Y, T3 deletes
//!   X). Correct tables serialize through the primary-bucket lock;
//!   SlabHash-like tables hit the window occasionally.
//! * [`replay_deterministic`] — this testbed's deterministic mode: a
//!   [`Fig41Schedule`] hook parks T1 right after it probes past the full
//!   primary bucket, guaranteeing the §4.1 interleaving every time. Only
//!   meaningful for unsynchronized tables (a locked table would hold its
//!   lock while parked and deadlock the schedule — which is itself the
//!   demonstration that locking closes the window), so the deterministic
//!   driver is used to *prove the bug exists* in SlabHash-like designs.

use std::sync::Arc;
use std::thread;

use crate::gpusim::race::Fig41Schedule;
use crate::tables::{
    slabhash_like::SlabHashLike, ConcurrentMap, TableConfig, TableKind, UpsertOp,
};
use crate::workloads::keys::UniformKeys;

/// Find, for one target bucket, a filler set that fills the bucket
/// completely plus (X, Y) with that primary bucket: X occupies the bucket,
/// Y is the contested key.
pub struct BucketScenario {
    pub bucket: usize,
    pub fillers: Vec<u64>,
    pub x: u64,
    pub y: u64,
}

/// Prepare scenarios for `n_buckets` distinct buckets of `table`:
/// per bucket, `bucket_capacity` keys that hash there (fillers + X) and
/// one extra contested key Y.
pub fn prepare_scenarios(
    table: &dyn ConcurrentMap,
    n_buckets: usize,
    bucket_capacity: usize,
    seed: u64,
) -> Vec<BucketScenario> {
    let nb = table.num_buckets();
    let mut gen = UniformKeys::new(seed);
    let mut per_bucket: std::collections::HashMap<usize, Vec<u64>> =
        std::collections::HashMap::new();
    let mut done = Vec::new();
    let mut attempts = 0usize;
    while done.len() < n_buckets && attempts < nb * bucket_capacity * 200 {
        attempts += 1;
        let k = gen.next_key();
        let b = table.primary_bucket(k);
        let v = per_bucket.entry(b).or_default();
        if v.len() < bucket_capacity + 1 {
            v.push(k);
            if v.len() == bucket_capacity + 1 {
                let mut v = per_bucket.remove(&b).unwrap();
                let y = v.pop().unwrap();
                let x = v.pop().unwrap();
                done.push(BucketScenario {
                    bucket: b,
                    fillers: v,
                    x,
                    y,
                });
            }
        }
    }
    done
}

/// Outcome of a replay over many buckets.
#[derive(Clone, Copy, Debug, Default)]
pub struct AdversarialReport {
    pub buckets_tested: u64,
    pub duplicates: u64,
    pub lost_keys: u64,
}

/// Statistical replay with real racing threads (both insert threads and
/// the delete thread start together).
pub fn replay_concurrent(
    table: Arc<dyn ConcurrentMap>,
    scenarios: &[BucketScenario],
) -> AdversarialReport {
    let mut report = AdversarialReport::default();
    for sc in scenarios {
        // Fill the primary bucket: fillers + X occupy every slot.
        for &k in &sc.fillers {
            table.upsert(k, 1, &UpsertOp::InsertIfUnique);
        }
        table.upsert(sc.x, 2, &UpsertOp::InsertIfUnique);
        let barrier = Arc::new(std::sync::Barrier::new(3));
        let mut hs = vec![];
        for role in 0..3u32 {
            let t = Arc::clone(&table);
            let b = Arc::clone(&barrier);
            let (x, y) = (sc.x, sc.y);
            hs.push(thread::spawn(move || {
                b.wait();
                match role {
                    0 | 1 => {
                        t.upsert(y, 10 + role as u64, &UpsertOp::InsertIfUnique);
                    }
                    _ => {
                        t.erase(x);
                    }
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        report.buckets_tested += 1;
        match table.count_copies(sc.y) {
            0 => report.lost_keys += 1,
            1 => {}
            _ => report.duplicates += 1,
        }
        // Clean up for the next scenario (best effort).
        table.erase(sc.y);
        for &k in &sc.fillers {
            table.erase(k);
        }
    }
    report
}

/// Deterministic Figure 4.1 replay against a fresh SlabHash-like table:
/// returns the copy count of Y after the forced interleaving (2 = the
/// race reproduced).
pub fn replay_deterministic_slabhash(slots: usize, seed: u64) -> (usize, AdversarialReport) {
    // Build a probe table first to discover a scenario, then rebuild with
    // the schedule hook targeting Y.
    let probe = SlabHashLike::new(TableConfig::for_kind(TableKind::SlabHashLike, slots));
    let bucket_cap = 8;
    let scenarios = prepare_scenarios(&probe, 1, bucket_cap, seed);
    let sc = &scenarios[0];

    let sched = Arc::new(Fig41Schedule::new(sc.y));
    let cfg = TableConfig::for_kind(TableKind::SlabHashLike, slots)
        .with_hook(Arc::clone(&sched) as Arc<dyn crate::gpusim::race::RaceHook>);
    let table = Arc::new(SlabHashLike::new(cfg));
    for &k in &sc.fillers {
        table.upsert(k, 1, &UpsertOp::InsertIfUnique);
    }
    table.upsert(sc.x, 2, &UpsertOp::InsertIfUnique);

    // T1: insert Y — will park after probing past the full primary.
    let t1 = {
        let t = Arc::clone(&table);
        let y = sc.y;
        thread::spawn(move || {
            t.upsert(y, 10, &UpsertOp::InsertIfUnique);
        })
    };
    sched.wait_t1_parked();
    // T3: delete X (frees a slot in the primary bucket).
    assert!(table.erase(sc.x), "X must be deletable");
    // T2: insert Y — sees the freed primary slot and claims it.
    table.upsert(sc.y, 11, &UpsertOp::InsertIfUnique);
    // Release T1: it completes its insert into the alternate bucket.
    sched.release_t1();
    t1.join().unwrap();

    let copies = table.count_copies(sc.y);
    let report = AdversarialReport {
        buckets_tested: 1,
        duplicates: (copies > 1) as u64,
        lost_keys: (copies == 0) as u64,
    };
    (copies, report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tables::build_table;

    #[test]
    fn deterministic_fig41_reproduces_slabhash_duplicate() {
        let (copies, report) = replay_deterministic_slabhash(4096, 0xF16);
        assert_eq!(
            copies, 2,
            "the §4.1 schedule must produce a duplicate in SlabHash-like"
        );
        assert_eq!(report.duplicates, 1);
    }

    #[test]
    fn locked_tables_pass_concurrent_replay() {
        for kind in [
            TableKind::Double,
            TableKind::DoubleMeta,
            TableKind::P2,
            TableKind::P2Meta,
            TableKind::Iceberg,
            TableKind::IcebergMeta,
            TableKind::Cuckoo,
            TableKind::Chaining,
        ] {
            let t = build_table(kind, 4096);
            let bucket_cap = match kind {
                TableKind::Chaining => 7,
                TableKind::DoubleMeta | TableKind::P2Meta => 32,
                TableKind::Iceberg | TableKind::IcebergMeta => 32,
                _ => 8,
            };
            let scenarios = prepare_scenarios(t.as_ref(), 8, bucket_cap, 0xAD0);
            assert!(!scenarios.is_empty(), "{kind:?}: no scenarios prepared");
            let report = replay_concurrent(t, &scenarios);
            assert_eq!(report.duplicates, 0, "{kind:?} duplicated a key (§4.1)");
            assert_eq!(report.lost_keys, 0, "{kind:?} lost a key");
        }
    }

    #[test]
    fn scenario_preparation_fills_buckets() {
        let t = build_table(TableKind::Double, 4096);
        let scs = prepare_scenarios(t.as_ref(), 4, 8, 1);
        assert_eq!(scs.len(), 4);
        for sc in &scs {
            assert_eq!(sc.fillers.len(), 7); // fillers + X = capacity
            assert_eq!(t.primary_bucket(sc.x), sc.bucket);
            assert_eq!(t.primary_bucket(sc.y), sc.bucket);
            for &k in &sc.fillers {
                assert_eq!(t.primary_bucket(k), sc.bucket);
            }
        }
    }
}
