//! Minimal CLI argument parsing (offline substitute for `clap`; see
//! DESIGN.md §Substitutions).
//!
//! Grammar: `warpspeed <subcommand> [--flag value]...`. Flags accept
//! `--key value` or `--key=value`.

use std::collections::HashMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: HashMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.flags.insert(stripped.to_string(), it.next().unwrap());
                } else {
                    out.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let a = parse(&["bench", "--slots", "4096", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("bench"));
        assert_eq!(a.get_usize("slots", 0), 4096);
        assert!(a.get_bool("verbose"));
    }

    #[test]
    fn parses_equals_form() {
        let a = parse(&["load", "--table=p2", "--seed=9"]);
        assert_eq!(a.get("table"), Some("p2"));
        assert_eq!(a.get_u64("seed", 0), 9);
    }

    #[test]
    fn positional_args_collected() {
        let a = parse(&["sptc", "one", "two"]);
        assert_eq!(a.positional, vec!["one", "two"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["x"]);
        assert_eq!(a.get_usize("missing", 7), 7);
        assert!(!a.get_bool("missing"));
    }
}
