//! WarpSpeed — a library of high-performance concurrent hash tables,
//! reproduced from McCoy & Pandey, "WarpSpeed: A High-Performance Library
//! for Concurrent GPU Hash Tables" (CS.DC 2025) as a Rust + JAX + Pallas
//! three-layer stack.
//!
//! Layers:
//! - L3 (this crate): the concurrent hash-table library, the GPU
//!   execution/memory-model simulator it runs on, the unified benchmarking
//!   framework, and a request-routing coordinator.
//! - L2 (python/compile/model.py): JAX bulk-query model over table
//!   snapshots, AOT-lowered to HLO text.
//! - L1 (python/compile/kernels/): Pallas probe/hash kernels called by L2.
//!
//! The original system is CUDA; this reproduction maps warps/tiles,
//! non-coherent L1 caches, morally-strong (acquire/release) accesses and
//! 128-bit vector loads onto a functional simulator (`gpusim`) so that the
//! paper's concurrency claims (adversarial races, lock-free queries,
//! probe-count behaviour) are exercised by real multi-threaded code.

pub mod gpusim;
pub mod hash;
pub mod prng;
pub mod quickprop;
pub mod alloc;
pub mod tables;
pub mod workloads;
pub mod apps;
pub mod bench;
pub mod coordinator;
pub mod runtime;
pub mod cli;

pub use tables::{ConcurrentMap, TableKind, UpsertOp, build_table, TableConfig, ConcurrencyMode};
