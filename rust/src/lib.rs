//! WarpSpeed — a library of high-performance concurrent hash tables,
//! reproduced from McCoy & Pandey, "WarpSpeed: A High-Performance Library
//! for Concurrent GPU Hash Tables" (CS.DC 2025) as a Rust + JAX + Pallas
//! three-layer stack.
//!
//! Layers:
//! - L3 (this crate): the concurrent hash-table library, the GPU
//!   execution/memory-model simulator it runs on, the unified benchmarking
//!   framework, and a request-routing coordinator.
//! - L2 (python/compile/model.py): JAX bulk-query model over table
//!   snapshots, AOT-lowered to HLO text.
//! - L1 (python/compile/kernels/): Pallas probe/hash kernels called by L2.
//!
//! The original system is CUDA; this reproduction maps warps/tiles,
//! non-coherent L1 caches, morally-strong (acquire/release) accesses and
//! 128-bit vector loads onto a functional simulator (`gpusim`) so that the
//! paper's concurrency claims (adversarial races, lock-free queries,
//! probe-count behaviour) are exercised by real multi-threaded code.
//!
//! # The batch-native operation pipeline
//!
//! GPU hash tables earn their throughput by amortizing cost over bulk
//! operations — tiles of threads share probes, and hosts call bulk
//! insert/retrieve entry points rather than single ops. Batching is
//! therefore a first-class concept across every layer here:
//!
//! * **Tables** ([`tables::ConcurrentMap`]): `upsert_bulk` /
//!   `query_bulk` / `erase_bulk` operate on slices and append into
//!   caller-provided buffers. All eight concurrent designs override
//!   them natively: the open-addressing designs (DoubleHT, P2HT,
//!   IcebergHT, plain and metadata variants) sort each batch by primary
//!   bucket so ONE lock acquisition and ONE shared bucket scan (a
//!   single tag-block probe on the metadata variants) serve every op
//!   that hashes there; CuckooHT groups by candidate-bucket triple so
//!   `lock_three` is taken once per group; ChainingHT performs one
//!   chain walk per bucket group. In-batch per-key order is preserved
//!   throughout.
//! * **Coordinator** ([`coordinator`]): a persistent shard-affine
//!   worker pool (spawned once, joined on drop) executes batches with
//!   submit/collect pipelining; batches partition per shard, split into
//!   maximal same-class runs (read-only batches skip the split), and
//!   dispatch whole runs through the bulk API; read runs can be served
//!   by the AOT-compiled PJRT bulk-query executable via
//!   [`coordinator::ReadOffload`].
//! * **Benches/apps**: the `bulk` exhibit ([`bench::bulk`]) sweeps
//!   scalar vs bulk across all eight concurrent designs with gpusim
//!   cost-model counters (lock acquisitions, atomics, cache lines per
//!   launch); the YCSB bench and the GPU-cache app
//!   ([`apps::caching::GpuCache::get_many`]) drive their hot loops
//!   through the same bulk entry points.
//!
//! # Online growth
//!
//! [`tables::GrowableMap`] wraps any design with WarpCore-style online
//! growth: a 2× successor is allocated at a load-factor trigger (or on
//! `Full`) and old buckets migrate incrementally in fixed batches
//! interleaved with traffic — old-then-new reads, successor-bound
//! upserts, dual erases, one lock per old primary bucket. The
//! coordinator drives shard migrations on its persistent workers and
//! turns `Full` into grow-and-retry ([`coordinator::CoordinatorConfig`]
//! `::growth`); the `grow` exhibit ([`bench::grow`]) measures it.
//!
//! # Online resharding
//!
//! Growth scales each shard's capacity; resharding scales the topology:
//! the coordinator's [`coordinator::Router`] is versioned by epoch, and
//! [`coordinator::ShardedTable::split_shards`] doubles the shard count
//! online — each shard splits into a pair, the extra routing-hash bit
//! re-routes exactly the keys that move, and migration interleaves with
//! traffic under the same locked claim-a-range discipline growth uses
//! (lifted to routing stripes). [`coordinator::ReshardPolicy`] triggers
//! it from load factor or queue depth; the `reshard` exhibit
//! ([`bench::reshard`]) drives a doubling under live mixed traffic
//! against a sequential oracle.
//!
//! # Shrink & merge — the lifecycle back down
//!
//! Both directions are online: [`tables::GrowthPolicy::shrink_below`]
//! arms a ½× low-watermark compaction through the identical migration
//! machinery in reverse (floor at the built capacity; refused when the
//! successor would start above the grow watermark), and
//! [`coordinator::ShardedTable::merge_shards`] halves the shard count —
//! children drain back into their parents under the same stripe locks
//! ([`coordinator::Router::halved`] / `merges_down`, the mirror of the
//! split property), and their capacity is reclaimed at the seal.
//! [`coordinator::ReshardPolicy`] gates policy merges behind a low-load
//! watermark, an idle queue, a consecutive-submit hysteresis, and a
//! structural no-oscillation guard; [`apps::caching::GpuCache::cooldown`]
//! walks a cooled cache back to its provisioning; the `shrink` exhibit
//! ([`bench::shrink`]) round-trips the whole lifecycle against a
//! sequential oracle.
//!
//! # Tiered storage — the frozen read-optimized tier
//!
//! Where the lifecycle above ends — a cooled, compacted, read-mostly
//! population — the frozen tier begins. [`tables::FrozenTable`] is an
//! immutable CHD minimal-perfect-hash snapshot of that population: one
//! displacement-array probe resolves each key to a unique bin, a fused
//! fingerprint/rank cache line rejects negatives in ≤ 2 line touches
//! and Elias-Fano-style ranks the hit into a dense pair store at
//! effective load factor 1.0. [`tables::TieredMap`] serves reads
//! frozen-first/mutable-second lock-free behind the unchanged
//! [`tables::ConcurrentMap`] surface; a write to a frozen key promotes
//! it back into the mutable tier (seed-then-invalidate under a stripe
//! lock, with an epoch bump so no reader trusts a stale frozen miss).
//! [`coordinator::ReshardPolicy::freeze_after_idle`] arms idle-streak
//! freeze jobs on the coordinator's shard-affine workers,
//! [`apps::caching::GpuCache::with_tiered`] freezes cache survivors at
//! cooldown, and the `freeze` exhibit ([`bench::freeze`]) measures
//! frozen vs mutable bulk launches against a sequential oracle.
//!
//! # Entry lifecycle — TTL, frequency, and segcache-style eviction
//!
//! Every design can carry per-entry lifecycle metadata
//! ([`tables::TableConfig::with_lifecycle`]): an 8-bit code packing a
//! saturating frequency counter and a coarse TTL deadline on a
//! 16-quantum ring, clocked by a deterministic logical
//! [`tables::LifecycleClock`]. The code is colocated with the
//! fingerprint/meta bytes, so the tag probe a lookup already performs
//! bumps the frequency — the gpusim line counters show zero extra
//! cache lines on the query hot path. `upsert_ttl` arms entries,
//! queries expire on read (a corpse answers as a miss and is never
//! resurrected), `sweep_expired` reclaims in bounded steps, and the
//! coordinator rides round-robin `Sweep` jobs on its shard-affine
//! workers ([`coordinator::ReshardPolicy`]
//! `::sweep_buckets_per_submit`, [`coordinator::Coordinator::sweep_now`]).
//! [`apps::caching::GpuCache::with_policy`] turns the metadata into
//! eviction policy: FIFO (default), TTL-first, or segcache-style
//! TTL-then-lowest-frequency; the `aging` exhibit ([`bench::aging`])
//! compares the three under zipfian churn.
//!
//! # Serving — the TCP tier
//!
//! [`server`] puts the whole stack behind sockets: a memcached-style
//! text data protocol (`get`/`gets`/`set`/`delete`/`incr`, TTL via the
//! `set` exptime field riding `Op::UpsertTtl`) plus a separate admin
//! port (`stats`/`version`/`tick`). Each connection's pipelined
//! requests become one coordinator batch per read turn, admission is
//! globally bounded (overload answers `SERVER_ERROR busy` instead of
//! queueing), and a slow client backpressures only itself. The wire
//! grammar lives in `docs/PROTOCOL.md`; `warpspeed serve --tcp` starts
//! it and the `serve` exhibit ([`bench::serve`]) drives loopback load
//! for p50/p99/p999 latency.
//!
//! # Hot keys — sampling + the lock-free front cache
//!
//! Hashing spreads keys uniformly but zipfian traffic concentrates
//! *ops*: the few hottest keys melt whichever shards own them.
//! [`coordinator::HotKeyPolicy`] arms a SpaceSaving sampler over the
//! keys seen at submit and a lock-free front cache of stamp-validated
//! replica slots ([`coordinator::hotkey`]): LIVE hits answer at submit
//! and never route, writes invalidate under the submit gate before
//! they are partitioned (so readers can never go backwards — per-key
//! FIFO holds through the cache, including across split/merge epoch
//! flips), and lifecycle-tick-stamped fills keep TTL expiry honest.
//! Per-shard routed/completed counters surface skew through
//! [`coordinator::LoadStats`], the admin `shard_skew` gauges, and
//! `ReshardPolicy::trigger_shard_pending`; the `hotkey` exhibit
//! ([`bench::hotkey`]) replays the zipfian mix, cache off vs on,
//! against a sequential oracle.
//!
//! The full layer map — who sits on whom, and the invariants each
//! layer owes the one above — is `docs/ARCHITECTURE.md`.

pub mod gpusim;
pub mod hash;
pub mod prng;
#[cfg(test)] // property-test harness, consumed only by #[cfg(test)] mods
pub mod quickprop;
pub mod alloc;
pub mod tables;
pub mod workloads;
pub mod apps;
pub mod bench;
pub mod coordinator;
pub mod runtime;
pub mod server;
pub mod cli;

pub use tables::{ConcurrentMap, TableKind, UpsertOp, build_table, TableConfig, ConcurrencyMode};
pub use tables::{FrozenTable, TieredMap};
