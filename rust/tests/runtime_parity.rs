//! Three-layer parity: the AOT-compiled Pallas bulk-query executable must
//! agree exactly with the Rust reference on random snapshots.
//!
//! Requires `make artifacts`; tests are skipped (pass trivially with a
//! notice) when artifacts are absent so `cargo test` works standalone.

use warpspeed::prng::Xoshiro256pp;
use warpspeed::runtime::{artifacts_dir, BulkQueryEngine};
use warpspeed::tables::kernel_table::KernelTable;

fn engine_or_skip() -> Option<BulkQueryEngine> {
    match BulkQueryEngine::load(&artifacts_dir()) {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("SKIP runtime parity (run `make artifacts`): {err:#}");
            None
        }
    }
}

#[test]
fn pjrt_matches_rust_reference_on_random_snapshots() {
    let Some(engine) = engine_or_skip() else { return };
    for seed in [1u64, 2, 3] {
        let mut rng = Xoshiro256pp::new(seed);
        let mut table = KernelTable::new(engine.nb, engine.b);
        let n_items = engine.nb * engine.b / 2;
        let mut present = Vec::new();
        while present.len() < n_items {
            let k = (rng.next_u64() as u32) | 1;
            if table.insert(k, rng.next_u64() as u32) {
                present.push(k);
            }
        }
        // Mixed queries: present, absent, and the empty-sentinel-adjacent.
        let mut queries = Vec::with_capacity(engine.query_batch);
        for i in 0..engine.query_batch {
            queries.push(match i % 3 {
                0 => present[rng.next_below(present.len() as u64) as usize],
                1 => (rng.next_u64() as u32) | 1,
                _ => (i as u32).max(1),
            });
        }
        let (vals, found) = engine.query_batch(&table, &queries).expect("execute");
        for (i, &q) in queries.iter().enumerate() {
            let want = table.query(q);
            assert_eq!(
                found[i],
                want.is_some(),
                "seed {seed} query {i} ({q:#x}): found mismatch"
            );
            if let Some(w) = want {
                assert_eq!(vals[i], w, "seed {seed} query {i} ({q:#x}): value mismatch");
            }
        }
    }
}

#[test]
fn query_all_handles_odd_batch_sizes() {
    let Some(engine) = engine_or_skip() else { return };
    let mut rng = Xoshiro256pp::new(9);
    let mut table = KernelTable::new(engine.nb, engine.b);
    let mut present = Vec::new();
    while present.len() < 1000 {
        let k = (rng.next_u64() as u32) | 1;
        if table.insert(k, k ^ 7) {
            present.push(k);
        }
    }
    // A non-multiple-of-batch query list.
    let queries: Vec<u32> = present.iter().copied().take(777).collect();
    let results = engine.query_all(&table, &queries).expect("query_all");
    assert_eq!(results.len(), 777);
    for (q, r) in queries.iter().zip(&results) {
        assert_eq!(*r, Some(q ^ 7));
    }
}

#[test]
fn engine_rejects_mismatched_geometry() {
    let Some(engine) = engine_or_skip() else { return };
    let wrong = KernelTable::new(engine.nb * 2, engine.b);
    let queries = vec![1u32; engine.query_batch];
    assert!(engine.query_batch(&wrong, &queries).is_err());
    let ok_table = KernelTable::new(engine.nb, engine.b);
    let short = vec![1u32; engine.query_batch - 1];
    assert!(engine.query_batch(&ok_table, &short).is_err());
}
