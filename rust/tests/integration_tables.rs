//! Cross-design integration tests: every concurrent design is held to the
//! same end-to-end contract under mixed concurrent workloads, churn, and
//! the paper's adversarial replay.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread;

use warpspeed::apps::adversarial::{prepare_scenarios, replay_concurrent};
use warpspeed::prng::Xoshiro256pp;
use warpspeed::tables::{build_table, TableKind, UpsertOp, UpsertResult};
use warpspeed::workloads::keys::distinct_keys;

/// Mixed concurrent workload: writers churn disjoint ranges while readers
/// hammer the whole space; then a full consistency audit.
#[test]
fn concurrent_stress_all_designs() {
    for kind in TableKind::CONCURRENT {
        let t = build_table(kind, 1 << 14);
        let n_threads = 4;
        let per = 1024;
        let all = Arc::new(distinct_keys(n_threads * per, 0x57E55));
        let read_hits = Arc::new(AtomicU64::new(0));
        let mut hs = Vec::new();
        for tid in 0..n_threads {
            let t = Arc::clone(&t);
            let all = Arc::clone(&all);
            let read_hits = Arc::clone(&read_hits);
            hs.push(thread::spawn(move || {
                let my = &all[tid * per..(tid + 1) * per];
                let mut rng = Xoshiro256pp::new(tid as u64);
                // Insert all, churn half, interleave global reads.
                for (i, &k) in my.iter().enumerate() {
                    assert_eq!(
                        t.upsert(k, (tid * per + i) as u64, &UpsertOp::InsertIfUnique),
                        UpsertResult::Inserted,
                        "{kind:?}"
                    );
                    if i % 5 == 0 {
                        let probe = all[rng.next_below((n_threads * per) as u64) as usize];
                        if let Some(v) = t.query(probe) {
                            // Value must be the index of that key.
                            let idx = all.iter().position(|&x| x == probe).unwrap();
                            assert_eq!(v, idx as u64, "{kind:?}: wrong value");
                            read_hits.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
                for (i, &k) in my.iter().enumerate() {
                    if i % 2 == 0 {
                        assert!(t.erase(k), "{kind:?}: erase failed");
                    }
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        assert!(read_hits.load(Ordering::Relaxed) > 0);
        // Audit: evens gone, odds present exactly once.
        for (i, &k) in all.iter().enumerate() {
            if i % 2 == 0 {
                assert_eq!(t.query(k), None, "{kind:?}: erased key resurfaced");
                assert_eq!(t.count_copies(k), 0, "{kind:?}");
            } else {
                assert_eq!(t.query(k), Some(i as u64), "{kind:?}: key lost");
                assert_eq!(t.count_copies(k), 1, "{kind:?}: duplicate");
            }
        }
    }
}

/// Concurrent upsert-accumulate: the compound op the paper says GPU
/// tables must support (k-mer counting shape). Total must be exact.
#[test]
fn concurrent_accumulation_is_exact() {
    for kind in TableKind::CONCURRENT {
        let t = build_table(kind, 4096);
        let keys = Arc::new(distinct_keys(32, 0xACC));
        let n_threads = 4;
        let adds_per_thread = 2000;
        let mut hs = Vec::new();
        for tid in 0..n_threads {
            let t = Arc::clone(&t);
            let keys = Arc::clone(&keys);
            hs.push(thread::spawn(move || {
                let mut rng = Xoshiro256pp::new(tid as u64 + 100);
                for _ in 0..adds_per_thread {
                    let k = keys[rng.next_below(keys.len() as u64) as usize];
                    t.upsert(k, 1, &UpsertOp::AddAssign);
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        let mut total = 0u64;
        for &k in keys.iter() {
            total += t.query(k).unwrap_or(0);
        }
        assert_eq!(
            total,
            (n_threads * adds_per_thread) as u64,
            "{kind:?}: lost or double-counted accumulations"
        );
    }
}

/// The §4.1 replay at integration scale (more buckets than the unit test).
#[test]
fn adversarial_replay_integration() {
    for kind in [TableKind::Double, TableKind::P2, TableKind::Cuckoo, TableKind::Chaining] {
        let t = build_table(kind, 1 << 14);
        let cap = kind.default_geometry().0;
        let scenarios = prepare_scenarios(t.as_ref(), 16, cap, 0x1711);
        assert!(scenarios.len() >= 8, "{kind:?}: too few scenarios");
        let rep = replay_concurrent(t, &scenarios);
        assert_eq!(rep.duplicates, 0, "{kind:?}");
        assert_eq!(rep.lost_keys, 0, "{kind:?}");
    }
}

/// Full-table lifecycle: fill to 90%, drain to 0, refill — capacity must
/// not rot (tombstone reuse works) for every open-addressing design.
#[test]
fn capacity_does_not_rot_across_generations() {
    for kind in [
        TableKind::Double,
        TableKind::DoubleMeta,
        TableKind::P2,
        TableKind::P2Meta,
        TableKind::Iceberg,
        TableKind::IcebergMeta,
        TableKind::Cuckoo,
    ] {
        let t = build_table(kind, 4096);
        let target = (t.capacity() as f64 * 0.85) as usize;
        for generation in 0..3 {
            let ks = distinct_keys(target, 0xF00 + generation);
            let mut inserted = Vec::new();
            for &k in &ks {
                if t.upsert(k, k ^ 1, &UpsertOp::InsertIfUnique) == UpsertResult::Inserted {
                    inserted.push(k);
                }
            }
            assert!(
                inserted.len() as f64 >= target as f64 * 0.97,
                "{kind:?}: generation {generation} only fit {}/{target}",
                inserted.len()
            );
            for &k in &inserted {
                assert_eq!(t.query(k), Some(k ^ 1), "{kind:?}");
                assert!(t.erase(k), "{kind:?}");
            }
            assert_eq!(t.len(), 0, "{kind:?}: leak after generation {generation}");
        }
    }
}
