//! Front-cache staleness under concurrent churn: a writer hammers the
//! hottest keys with strictly increasing values while reader threads
//! spin queries through the same coordinator — every observation must
//! be monotonically non-decreasing (a single regression means a stale
//! front-cache hit), including across a forced split and merge epoch
//! flip mid-churn. This is the multithreaded counterpart of the
//! single-threaded lifecycle tests in `coordinator::exec` — here the
//! submit gate, the fill tickets, and the invalidation stamps race for
//! real.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use warpspeed::coordinator::{
    Batch, Coordinator, CoordinatorConfig, HotKeyPolicy, Op, OpResult,
};
use warpspeed::tables::{GrowthPolicy, TableKind};
use warpspeed::workloads::keys::distinct_keys;

fn hot_coordinator() -> Coordinator {
    Coordinator::new(CoordinatorConfig {
        kind: TableKind::P2Meta,
        total_slots: 16 * 1024,
        n_shards: 4,
        n_workers: 4,
        max_batch: 64,
        growth: Some(GrowthPolicy::default()),
        reshard: None, // epoch flips are forced at fixed points
        hotkey: Some(HotKeyPolicy {
            // Promote aggressively so the cache is in play from the
            // first few reads and stays under write fire throughout.
            sample_every: 1,
            promote_min_count: 2,
            ..HotKeyPolicy::default()
        }),
    })
}

#[test]
fn readers_never_observe_stale_values_under_write_churn() {
    const WRITES: u64 = 1500;
    let c = Arc::new(hot_coordinator());
    let hot: Vec<u64> = distinct_keys(4, 0xC0);
    let cold: Vec<u64> = distinct_keys(64, 0xC1);
    // Preload: hot keys at version 0, cold keys as routing ballast.
    let mut ops = Vec::new();
    for &k in &hot {
        ops.push(Op::Upsert(k, 0));
    }
    for &k in &cold {
        ops.push(Op::Upsert(k, 1));
    }
    c.run_stream(ops);

    let done = Arc::new(AtomicBool::new(false));
    let readers: Vec<_> = (0..2)
        .map(|_| {
            let c = Arc::clone(&c);
            let hot = hot.clone();
            let cold = cold.clone();
            let done = Arc::clone(&done);
            std::thread::spawn(move || {
                let mut last = vec![0u64; hot.len()];
                let mut rounds = 0u64;
                while !done.load(Ordering::Relaxed) {
                    // Hot queries plus a cold one, so batches also carry
                    // traffic the cache must leave untouched.
                    let mut ops: Vec<(u64, Op)> = hot
                        .iter()
                        .enumerate()
                        .map(|(i, &k)| (i as u64, Op::Query(k)))
                        .collect();
                    ops.push((hot.len() as u64, Op::Query(cold[rounds as usize % cold.len()])));
                    let res = c.execute(&Batch { ops });
                    for (i, &(_, r)) in res.iter().take(hot.len()).enumerate() {
                        let OpResult::Value(Some(v)) = r else {
                            panic!("hot key {i} vanished: {r:?}");
                        };
                        assert!(
                            v >= last[i],
                            "stale read: hot key {i} went backwards {} -> {v}",
                            last[i]
                        );
                        last[i] = v;
                    }
                    rounds += 1;
                }
                (last, rounds)
            })
        })
        .collect();

    // The writer: strictly increasing versions on every hot key, with
    // the topology forced through a split and back down to the original
    // shard count mid-churn — invalidation must hold across both epoch
    // directions.
    for v in 1..=WRITES {
        let ops: Vec<(u64, Op)> =
            hot.iter().enumerate().map(|(i, &k)| (i as u64, Op::Upsert(k, v))).collect();
        let res = c.execute(&Batch { ops });
        assert!(res.iter().all(|&(_, r)| r == OpResult::Upserted(false)));
        if v == WRITES / 3 {
            assert!(c.request_reshard(), "forced split must start");
        }
        if v == 2 * WRITES / 3 {
            assert!(c.finish_resharding(), "split must seal before the merge");
            assert!(c.request_merge(), "forced merge must start");
        }
    }
    // Quiet tail with the writer silent: readers arm, fill, and hit the
    // final version, so the run provably exercises the cache hit path.
    let settle = std::time::Instant::now();
    loop {
        let st = c.hotkey_stats().expect("hotkey armed");
        if st.hits > 0 || settle.elapsed() > std::time::Duration::from_secs(10) {
            break;
        }
        std::thread::yield_now();
    }
    done.store(true, Ordering::Relaxed);
    for r in readers {
        let (last, rounds) = r.join().expect("reader thread");
        assert!(rounds > 0, "reader never completed a round");
        // Monotonicity was asserted in-loop; the tail must have caught
        // up to the final version once the writer went quiet.
        for (i, &v) in last.iter().enumerate() {
            assert!(v <= WRITES, "hot key {i} read a version never written: {v}");
        }
    }
    // Final ground truth after the churn: the table holds the last
    // version, served identically through cache and shards.
    assert!(c.finish_resharding());
    let final_reads = c.run_stream(hot.iter().map(|&k| Op::Query(k)));
    for r in &final_reads {
        assert_eq!(*r, OpResult::Value(Some(WRITES)));
    }
    let st = c.hotkey_stats().unwrap();
    assert!(st.hits > 0, "front cache never served a hit: {st:?}");
    assert!(st.invalidations > 0, "writer churn never invalidated: {st:?}");
    assert!(st.fills > 0, "no fill ever committed: {st:?}");
}

#[test]
fn erase_churn_never_resurrects_through_the_cache() {
    // Writer alternates upsert/erase on one hot key; readers must only
    // ever see the value written by the latest upsert or absence —
    // never a value after its erase was submitted before their query.
    const ROUNDS: u64 = 400;
    let c = Arc::new(hot_coordinator());
    let k = distinct_keys(1, 0xC2)[0];
    c.run_stream([Op::Upsert(k, 1)]);

    let done = Arc::new(AtomicBool::new(false));
    let reader = {
        let c = Arc::clone(&c);
        let done = Arc::clone(&done);
        std::thread::spawn(move || {
            let mut last_seen = 0u64;
            while !done.load(Ordering::Relaxed) {
                let res = c.execute(&Batch { ops: vec![(0, Op::Query(k))] });
                match res[0].1 {
                    OpResult::Value(Some(v)) => {
                        assert!(
                            v >= last_seen,
                            "resurrected stale value {v} after seeing {last_seen}"
                        );
                        last_seen = v;
                    }
                    OpResult::Value(None) => {}
                    other => panic!("unexpected: {other:?}"),
                }
            }
        })
    };
    for v in 2..=ROUNDS {
        c.run_stream([Op::Erase(k), Op::Upsert(k, v)]);
    }
    done.store(true, Ordering::Relaxed);
    reader.join().expect("reader thread");
    let r = c.run_stream([Op::Query(k)]);
    assert_eq!(r[0], OpResult::Value(Some(ROUNDS)));
}
