//! End-to-end serving-tier tests: a real `TcpStream` client against an
//! in-process [`Server`] on ephemeral loopback ports, asserting EXACT
//! response bytes for every command in `docs/PROTOCOL.md` — data
//! protocol, admin protocol, TTL via admin `tick`, pipelining, resync
//! after errors, the overload path, and the connection cap.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use warpspeed::coordinator::{Coordinator, CoordinatorConfig};
use warpspeed::server::{Server, ServerConfig};
use warpspeed::tables::{LifecycleClock, LifecycleConfig, TableKind};

fn start(ttl: bool, server_cfg: ServerConfig) -> (Server, Option<Arc<LifecycleClock>>) {
    let cfg = CoordinatorConfig {
        kind: if ttl { TableKind::P2Meta } else { TableKind::Double },
        total_slots: 16 * 1024,
        n_shards: 4,
        n_workers: 2,
        max_batch: 256,
        growth: None,
        reshard: None,
        hotkey: None,
    };
    let (coord, clock) = if ttl {
        let lc = LifecycleConfig::new(1);
        let clock = lc.clock.clone();
        (Coordinator::new_with_lifecycle(cfg, lc), Some(clock))
    } else {
        (Coordinator::new(cfg), None)
    };
    let server = Server::start(Arc::new(coord), clock.clone(), server_cfg).expect("bind");
    (server, clock)
}

fn loopback() -> ServerConfig {
    ServerConfig {
        data_addr: "127.0.0.1:0".into(),
        admin_addr: "127.0.0.1:0".into(),
        ..ServerConfig::default()
    }
}

fn connect(addr: std::net::SocketAddr) -> TcpStream {
    let sock = TcpStream::connect(addr).expect("connect");
    sock.set_nodelay(true).unwrap();
    // Generous: only hit when a response goes missing (test failure).
    sock.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    sock
}

/// Send `req`, then read and assert EXACTLY `want` — byte-for-byte,
/// `\r\n` included.
fn roundtrip(sock: &mut TcpStream, req: &str, want: &str) {
    sock.write_all(req.as_bytes()).expect("send");
    let mut got = vec![0u8; want.len()];
    sock.read_exact(&mut got).expect("full response");
    assert_eq!(
        String::from_utf8_lossy(&got),
        want,
        "exact response mismatch for request {req:?}"
    );
}

/// After `quit`, the server closes: EOF, no trailing bytes.
fn assert_closed(sock: &mut TcpStream) {
    sock.write_all(b"quit\r\n").expect("send quit");
    let mut rest = Vec::new();
    sock.read_to_end(&mut rest).expect("EOF after quit");
    assert_eq!(rest, b"", "no bytes may follow the final response");
}

#[test]
fn data_protocol_exact_responses() {
    let (server, _) = start(false, loopback());
    let mut c = connect(server.data_addr());

    roundtrip(&mut c, "set 7 0 0 4\r\n1234\r\n", "STORED\r\n");
    roundtrip(&mut c, "get 7\r\n", "VALUE 7 0 4\r\n1234\r\nEND\r\n");
    roundtrip(&mut c, "gets 7\r\n", "VALUE 7 0 4\r\n1234\r\nEND\r\n");
    // Multi-key get: misses are omitted, END always arrives.
    roundtrip(&mut c, "get 7 8\r\n", "VALUE 7 0 4\r\n1234\r\nEND\r\n");
    roundtrip(&mut c, "get 8\r\n", "END\r\n");
    // incr: in-place add + read-back in one batch.
    roundtrip(&mut c, "incr 7 6\r\n", "1240\r\n");
    roundtrip(&mut c, "incr 99 5\r\n", "5\r\n"); // absent key: created at delta
    roundtrip(&mut c, "delete 7\r\n", "DELETED\r\n");
    roundtrip(&mut c, "delete 7\r\n", "NOT_FOUND\r\n");
    roundtrip(&mut c, "get 7\r\n", "END\r\n");
    // Error taxonomy + resync: the connection survives each of these.
    roundtrip(&mut c, "bogus\r\n", "ERROR\r\n");
    roundtrip(&mut c, "set 7 1 0 3\r\n123\r\n", "CLIENT_ERROR flags must be 0\r\n");
    roundtrip(&mut c, "get 99\r\n", "VALUE 99 0 1\r\n5\r\nEND\r\n");
    roundtrip(&mut c, "set 1 0 0 3\r\n12345\r\n", "CLIENT_ERROR bad data chunk\r\n");
    roundtrip(&mut c, "get 99\r\n", "VALUE 99 0 1\r\n5\r\nEND\r\n");
    // TTL'd set on a server without --ttl.
    roundtrip(&mut c, "set 5 0 9 1\r\n7\r\n", "SERVER_ERROR ttl disabled\r\n");
    assert_closed(&mut c);

    // Counters reflect the session. cmd_set counts well-formed set
    // requests only (the flags/data-chunk rejects are parse_errors);
    // the ttl-disabled set parsed fine, so it counts.
    let stats = server.stats();
    let relaxed = std::sync::atomic::Ordering::Relaxed;
    assert_eq!(stats.cmd_set.load(relaxed), 2);
    assert_eq!(stats.cmd_get.load(relaxed), 7);
    assert_eq!(stats.cmd_delete.load(relaxed), 2);
    assert_eq!(stats.cmd_incr.load(relaxed), 2);
    assert_eq!(stats.parse_errors.load(relaxed), 3);
    assert_eq!(stats.total_connections.load(relaxed), 1);
    assert_eq!(stats.curr_connections.load(relaxed), 0);
    server.shutdown();
}

#[test]
fn pipelined_burst_answers_in_order() {
    let (server, _) = start(false, loopback());
    let mut c = connect(server.data_addr());
    let mut req = String::new();
    let mut want = String::new();
    for i in 0..100u64 {
        req.push_str(&format!("set {i} 0 0 2\r\n9{}\r\n", i % 10));
        want.push_str("STORED\r\n");
        req.push_str(&format!("get {i}\r\n"));
        want.push_str(&format!("VALUE {i} 0 2\r\n9{}\r\nEND\r\n", i % 10));
        if i % 5 == 0 {
            req.push_str(&format!("delete {i}\r\n"));
            want.push_str("DELETED\r\n");
        }
    }
    // One write: 220 pipelined requests cross multiple session windows.
    roundtrip(&mut c, &req, &want);
    assert_closed(&mut c);
    server.shutdown();
}

#[test]
fn ttl_set_expires_after_admin_ticks() {
    let (server, clock) = start(true, loopback());
    let clock = clock.expect("ttl server has a clock");
    let mut c = connect(server.data_addr());
    let mut a = connect(server.admin_addr());

    roundtrip(&mut c, "set 5 0 2 3\r\n111\r\n", "STORED\r\n"); // expires at tick 2
    roundtrip(&mut c, "set 6 0 0 3\r\n222\r\n", "STORED\r\n"); // immortal
    roundtrip(&mut c, "get 5 6\r\n", "VALUE 5 0 3\r\n111\r\nVALUE 6 0 3\r\n222\r\nEND\r\n");
    roundtrip(&mut a, "tick 3\r\n", "TICK 3\r\n");
    assert_eq!(clock.now(), 3);
    roundtrip(&mut c, "get 5 6\r\n", "VALUE 6 0 3\r\n222\r\nEND\r\n");
    // Admin stats reflect both protocols' traffic.
    a.write_all(b"stats\r\n").unwrap();
    let mut text = String::new();
    let mut buf = [0u8; 4096];
    while !text.contains("END\r\n") {
        let n = a.read(&mut buf).expect("stats bytes");
        assert!(n > 0);
        text.push_str(std::str::from_utf8(&buf[..n]).unwrap());
    }
    for needle in [
        "STAT cmd_set 2\r\n",
        "STAT cmd_get 2\r\n",
        "STAT get_hits 3\r\n",
        "STAT get_misses 1\r\n",
        "STAT lifecycle_tick 3\r\n",
        "STAT n_shards 4\r\n",
    ] {
        assert!(text.contains(needle), "missing {needle:?} in stats:\n{text}");
    }
    roundtrip(&mut a, "version\r\n", &format!("VERSION warpspeed/{}\r\n", env!("CARGO_PKG_VERSION")));
    assert_closed(&mut c);
    server.shutdown();
}

#[test]
fn overloaded_server_answers_busy() {
    // Admission cap 0: every table-touching window is refused, one
    // busy line per request, parse errors keep their own reply.
    let (server, _) = start(false, ServerConfig { max_inflight_ops: 0, ..loopback() });
    let mut c = connect(server.data_addr());
    roundtrip(&mut c, "set 1 0 0 1\r\n5\r\n", "SERVER_ERROR busy\r\n");
    roundtrip(&mut c, "get 1 2 3\r\n", "SERVER_ERROR busy\r\n");
    roundtrip(&mut c, "bogus\r\n", "ERROR\r\n");
    let stats = server.stats();
    assert_eq!(stats.busy_rejections.load(std::sync::atomic::Ordering::Relaxed), 2);
    assert_closed(&mut c);
    server.shutdown();
}

#[test]
fn connection_cap_refuses_with_a_reason() {
    let (server, _) = start(false, ServerConfig { max_connections: 0, ..loopback() });
    let mut c = connect(server.data_addr());
    let mut text = String::new();
    c.read_to_string(&mut text).expect("refusal then close");
    assert_eq!(text, "SERVER_ERROR too many connections\r\n");
    let stats = server.stats();
    assert_eq!(stats.rejected_connections.load(std::sync::atomic::Ordering::Relaxed), 1);
    server.shutdown();
}
