//! End-to-end coordinator tests: YCSB served through the router/batcher/
//! executor stack, and the full benchmark suite smoke-checked at tiny
//! scale so every paper exhibit stays regenerable.

use warpspeed::bench::{self, BenchEnv};
use warpspeed::coordinator::{Coordinator, CoordinatorConfig, Op, OpResult, ReshardPolicy};
use warpspeed::tables::TableKind;
use warpspeed::workloads::keys::distinct_keys;
use warpspeed::workloads::ycsb::{Workload, YcsbOp, YcsbStream};

#[test]
fn coordinator_serves_ycsb_consistently() {
    let coord = Coordinator::new(CoordinatorConfig {
        kind: TableKind::DoubleMeta,
        total_slots: 16 * 1024,
        n_shards: 4,
        n_workers: 2,
        max_batch: 256,
        growth: None,
        reshard: None,
        hotkey: None,
    });
    let universe = distinct_keys(8 * 1024, 0xE2E);
    let load_results = coord.run_stream(universe.iter().map(|&k| Op::Upsert(k, k ^ 3)));
    assert!(load_results.iter().all(|r| *r == OpResult::Upserted(true)));

    let mut oracle: std::collections::HashMap<u64, u64> =
        universe.iter().map(|&k| (k, k ^ 3)).collect();
    let mut stream = YcsbStream::new(&universe, Workload::A, 5);
    let ops: Vec<YcsbOp> = stream.batch(20_000);
    let coord_ops: Vec<Op> = ops
        .iter()
        .map(|op| match *op {
            YcsbOp::Read(k) => Op::Query(k),
            YcsbOp::Update(k, v) => Op::Upsert(k, v),
        })
        .collect();
    let results = coord.run_stream(coord_ops);
    for (op, res) in ops.iter().zip(&results) {
        match *op {
            YcsbOp::Read(k) => {
                assert_eq!(*res, OpResult::Value(oracle.get(&k).copied()));
            }
            YcsbOp::Update(k, v) => {
                oracle.insert(k, v);
                assert!(matches!(res, OpResult::Upserted(_)));
            }
        }
    }
}

#[test]
fn every_bench_exhibit_regenerates() {
    let env = BenchEnv {
        slots: 4096,
        iterations: 8,
        seed: 0xB1B,
    };
    let exhibits: Vec<(&str, fn(&BenchEnv) -> String)> = vec![
        ("probes/Table5.1", bench::probes::run),
        ("reshard", bench::reshard::run),
        ("shrink", bench::shrink::run),
        ("load/Fig6.1", bench::load::run),
        ("aging/Fig6.2", bench::aging::run),
        ("caching/Fig6.3", bench::caching::run),
        ("ycsb/Table6.2", bench::ycsb::run),
        ("sptc/Table6.1", bench::sptc::run),
        ("space/§6.1", bench::space::run),
        ("adversarial/§4.1", bench::adversarial::run),
    ];
    for (name, f) in exhibits {
        let out = f(&env);
        assert!(out.contains("=="), "{name}: no table/series emitted:\n{out}");
        assert!(out.len() > 100, "{name}: suspiciously short output");
    }
}

#[test]
fn scaling_bench_regenerates() {
    // Separate (slower) smoke for the size sweep at minimal scale.
    let env = BenchEnv {
        slots: 2048,
        iterations: 4,
        seed: 1,
    };
    let out = bench::scaling::run(&env);
    assert!(out.contains("Figure 6.4"));
}

#[test]
fn coordinator_reshards_under_ycsb_traffic() {
    // End-to-end topology scaling: a deliberately narrow 2-shard
    // coordinator with a load-factor reshard trigger serves a YCSB-A
    // stream over a growing universe. The shard count must double at
    // least once mid-serve, the pool must widen with it, and every
    // result must match the sequential oracle — zero lost or duplicated
    // ops across the epoch changes.
    let coord = Coordinator::new(CoordinatorConfig {
        kind: TableKind::P2Meta,
        total_slots: 8 * 1024,
        n_shards: 2,
        n_workers: 4,
        max_batch: 256,
        growth: Some(warpspeed::tables::GrowthPolicy::default()),
        reshard: Some(ReshardPolicy {
            trigger_load_factor: 0.6,
            migration_stripes: 64,
            max_shards: 16,
            ..Default::default()
        }),
        hotkey: None,
    });
    assert_eq!(coord.n_workers(), 2, "pool clamps to the initial shard count");
    let universe = distinct_keys(12 * 1024, 0x12E5);
    let mut oracle: std::collections::HashMap<u64, u64> = std::collections::HashMap::new();
    // Phase 1: load 1.5× the provisioning — crosses the 0.6 trigger.
    let load_results = coord.run_stream(universe.iter().map(|&k| Op::Upsert(k, k ^ 3)));
    assert!(
        load_results.iter().all(|r| *r == OpResult::Upserted(true)),
        "load phase rejected or duplicated an insert"
    );
    for &k in &universe {
        oracle.insert(k, k ^ 3);
    }
    assert!(coord.table.epoch() >= 1, "load never fired the reshard trigger");
    assert!(coord.n_workers() >= 4, "pool never widened with the topology");
    // Phase 2: serve YCSB-A (50/50 read/update) across whatever split
    // migration is still in flight.
    let mut stream = YcsbStream::new(&universe, Workload::A, 5);
    let ops: Vec<YcsbOp> = stream.batch(20_000);
    let coord_ops: Vec<Op> = ops
        .iter()
        .map(|op| match *op {
            YcsbOp::Read(k) => Op::Query(k),
            YcsbOp::Update(k, v) => Op::Upsert(k, v),
        })
        .collect();
    let results = coord.run_stream(coord_ops);
    for (op, res) in ops.iter().zip(&results) {
        match *op {
            YcsbOp::Read(k) => {
                assert_eq!(*res, OpResult::Value(oracle.get(&k).copied()));
            }
            YcsbOp::Update(k, v) => {
                oracle.insert(k, v);
                assert!(matches!(res, OpResult::Upserted(_)));
            }
        }
    }
    // Quiesce and audit the final topology.
    assert!(coord.finish_resharding(), "split never completed");
    assert!(coord.finish_migrations());
    assert!(coord.table.n_shards() >= 4);
    assert_eq!(coord.table.len(), oracle.len(), "keys lost or duplicated");
    let (max, min) = coord.table.balance();
    assert!(min > 0, "an empty shard after resharding: {min}..{max}");
}
