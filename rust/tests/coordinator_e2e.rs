//! End-to-end coordinator tests: YCSB served through the router/batcher/
//! executor stack, and the full benchmark suite smoke-checked at tiny
//! scale so every paper exhibit stays regenerable.

use warpspeed::bench::{self, BenchEnv};
use warpspeed::coordinator::{Coordinator, CoordinatorConfig, Op, OpResult};
use warpspeed::tables::TableKind;
use warpspeed::workloads::keys::distinct_keys;
use warpspeed::workloads::ycsb::{Workload, YcsbOp, YcsbStream};

#[test]
fn coordinator_serves_ycsb_consistently() {
    let coord = Coordinator::new(CoordinatorConfig {
        kind: TableKind::DoubleMeta,
        total_slots: 16 * 1024,
        n_shards: 4,
        n_workers: 2,
        max_batch: 256,
        growth: None,
    });
    let universe = distinct_keys(8 * 1024, 0xE2E);
    let load_results = coord.run_stream(universe.iter().map(|&k| Op::Upsert(k, k ^ 3)));
    assert!(load_results.iter().all(|r| *r == OpResult::Upserted(true)));

    let mut oracle: std::collections::HashMap<u64, u64> =
        universe.iter().map(|&k| (k, k ^ 3)).collect();
    let mut stream = YcsbStream::new(&universe, Workload::A, 5);
    let ops: Vec<YcsbOp> = stream.batch(20_000);
    let coord_ops: Vec<Op> = ops
        .iter()
        .map(|op| match *op {
            YcsbOp::Read(k) => Op::Query(k),
            YcsbOp::Update(k, v) => Op::Upsert(k, v),
        })
        .collect();
    let results = coord.run_stream(coord_ops);
    for (op, res) in ops.iter().zip(&results) {
        match *op {
            YcsbOp::Read(k) => {
                assert_eq!(*res, OpResult::Value(oracle.get(&k).copied()));
            }
            YcsbOp::Update(k, v) => {
                oracle.insert(k, v);
                assert!(matches!(res, OpResult::Upserted(_)));
            }
        }
    }
}

#[test]
fn every_bench_exhibit_regenerates() {
    let env = BenchEnv {
        slots: 4096,
        iterations: 8,
        seed: 0xB1B,
    };
    let exhibits: Vec<(&str, fn(&BenchEnv) -> String)> = vec![
        ("probes/Table5.1", bench::probes::run),
        ("load/Fig6.1", bench::load::run),
        ("aging/Fig6.2", bench::aging::run),
        ("caching/Fig6.3", bench::caching::run),
        ("ycsb/Table6.2", bench::ycsb::run),
        ("sptc/Table6.1", bench::sptc::run),
        ("space/§6.1", bench::space::run),
        ("adversarial/§4.1", bench::adversarial::run),
    ];
    for (name, f) in exhibits {
        let out = f(&env);
        assert!(out.contains("=="), "{name}: no table/series emitted:\n{out}");
        assert!(out.len() > 100, "{name}: suspiciously short output");
    }
}

#[test]
fn scaling_bench_regenerates() {
    // Separate (slower) smoke for the size sweep at minimal scale.
    let env = BenchEnv {
        slots: 2048,
        iterations: 4,
        seed: 1,
    };
    let out = bench::scaling::run(&env);
    assert!(out.contains("Figure 6.4"));
}
