//! Bulk-vs-scalar oracle property (the batch-native pipeline's
//! correctness contract): for every one of the eight concurrent designs,
//! driving the bulk API with coordinator-shaped batches — mixed
//! upsert/accumulate/query/erase ops over a tiny universe, so batches
//! are full of duplicate keys — produces results identical to a scalar
//! twin table driven op-by-op, and both agree with a `HashMap` oracle
//! (the `coordinator_e2e` oracle pattern).

use std::collections::HashMap;

use warpspeed::coordinator::{Coordinator, CoordinatorConfig, Op, OpResult};
use warpspeed::prng::Xoshiro256pp;
use warpspeed::tables::{build_table, TableKind, UpsertOp, UpsertResult};
use warpspeed::workloads::keys::distinct_keys;

/// Op classes mirror `coordinator::exec`'s run splitting: a mixed batch
/// executes as maximal same-class runs, each dispatched as one bulk call.
#[derive(Clone, Copy, PartialEq)]
enum Class {
    Put,
    Add,
    Get,
    Del,
}

fn gen_batch(rng: &mut Xoshiro256pp, universe: &[u64], len: usize) -> Vec<(Class, u64, u64)> {
    (0..len)
        .map(|_| {
            let k = universe[rng.next_below(universe.len() as u64) as usize];
            match rng.next_below(4) {
                0 => (Class::Put, k, rng.next_below(1_000)),
                1 => (Class::Add, k, rng.next_below(100)),
                2 => (Class::Get, k, 0),
                _ => (Class::Del, k, 0),
            }
        })
        .collect()
}

#[test]
fn bulk_matches_scalar_oracle_for_all_eight_designs() {
    for kind in TableKind::CONCURRENT {
        let bulk_t = build_table(kind, 4096);
        let scalar_t = build_table(kind, 4096);
        let mut oracle: HashMap<u64, u64> = HashMap::new();
        let mut rng = Xoshiro256pp::new(0xB01C ^ kind as u64);
        let universe = distinct_keys(64, 0xB02C ^ kind as u64);
        for round in 0..40 {
            let batch = gen_batch(&mut rng, &universe, 256);
            // Split into maximal same-class runs, dispatch each as ONE
            // bulk call — exactly what the coordinator executor does.
            let mut s = 0;
            while s < batch.len() {
                let class = batch[s].0;
                let mut e = s + 1;
                while e < batch.len() && batch[e].0 == class {
                    e += 1;
                }
                let run = &batch[s..e];
                match class {
                    Class::Put | Class::Add => {
                        let op = if class == Class::Put {
                            UpsertOp::Overwrite
                        } else {
                            UpsertOp::AddAssign
                        };
                        let pairs: Vec<(u64, u64)> =
                            run.iter().map(|&(_, k, v)| (k, v)).collect();
                        let mut got: Vec<UpsertResult> = Vec::new();
                        bulk_t.upsert_bulk(&pairs, &op, &mut got);
                        assert_eq!(got.len(), pairs.len());
                        for (i, &(k, v)) in pairs.iter().enumerate() {
                            let want = scalar_t.upsert(k, v, &op);
                            assert_eq!(
                                got[i], want,
                                "{kind:?}: round {round} upsert #{i} key {k:#x}"
                            );
                            if class == Class::Put {
                                oracle.insert(k, v);
                            } else {
                                oracle
                                    .entry(k)
                                    .and_modify(|x| *x = x.wrapping_add(v))
                                    .or_insert(v);
                            }
                        }
                    }
                    Class::Get => {
                        let keys: Vec<u64> = run.iter().map(|&(_, k, _)| k).collect();
                        let mut got: Vec<Option<u64>> = Vec::new();
                        bulk_t.query_bulk(&keys, &mut got);
                        assert_eq!(got.len(), keys.len());
                        for (i, &k) in keys.iter().enumerate() {
                            assert_eq!(
                                got[i],
                                oracle.get(&k).copied(),
                                "{kind:?}: round {round} query #{i} key {k:#x}"
                            );
                            assert_eq!(got[i], scalar_t.query(k), "{kind:?}");
                        }
                    }
                    Class::Del => {
                        let keys: Vec<u64> = run.iter().map(|&(_, k, _)| k).collect();
                        let mut got: Vec<bool> = Vec::new();
                        bulk_t.erase_bulk(&keys, &mut got);
                        assert_eq!(got.len(), keys.len());
                        for (i, &k) in keys.iter().enumerate() {
                            let want = scalar_t.erase(k);
                            assert_eq!(
                                got[i], want,
                                "{kind:?}: round {round} erase #{i} key {k:#x}"
                            );
                            assert_eq!(got[i], oracle.remove(&k).is_some(), "{kind:?}");
                        }
                    }
                }
                s = e;
            }
        }
        // Final-state audit: bulk table ≡ oracle, no duplicate copies.
        assert_eq!(bulk_t.len(), oracle.len(), "{kind:?}");
        for &k in &universe {
            assert_eq!(bulk_t.query(k), oracle.get(&k).copied(), "{kind:?}");
            assert!(bulk_t.count_copies(k) <= 1, "{kind:?}: duplicate {k:#x}");
        }
    }
}

/// The same property served end-to-end through the coordinator's
/// batch-native executor (batcher → shard partition → run split → bulk
/// dispatch), for every concurrent design.
#[test]
fn coordinator_bulk_dispatch_matches_oracle_for_all_designs() {
    for kind in TableKind::CONCURRENT {
        let c = Coordinator::new(CoordinatorConfig {
            kind,
            total_slots: 16 * 1024,
            n_shards: 4,
            n_workers: 2,
            max_batch: 128,
        });
        let ks = distinct_keys(64, 0xC0DE ^ kind as u64);
        let mut oracle: HashMap<u64, u64> = HashMap::new();
        let mut rng = Xoshiro256pp::new(0xC1DE ^ kind as u64);
        let mut ops = Vec::new();
        let mut expected = Vec::new();
        for _ in 0..4_000 {
            let k = ks[rng.next_below(64) as usize];
            match rng.next_below(4) {
                0 => {
                    let v = rng.next_below(1_000);
                    ops.push(Op::Upsert(k, v));
                    let was_new = oracle.insert(k, v).is_none();
                    expected.push(OpResult::Upserted(was_new));
                }
                1 => {
                    let v = rng.next_below(100);
                    ops.push(Op::UpsertAdd(k, v));
                    match oracle.get_mut(&k) {
                        Some(x) => {
                            *x = x.wrapping_add(v);
                            expected.push(OpResult::Upserted(false));
                        }
                        None => {
                            oracle.insert(k, v);
                            expected.push(OpResult::Upserted(true));
                        }
                    }
                }
                2 => {
                    ops.push(Op::Query(k));
                    expected.push(OpResult::Value(oracle.get(&k).copied()));
                }
                _ => {
                    ops.push(Op::Erase(k));
                    expected.push(OpResult::Erased(oracle.remove(&k).is_some()));
                }
            }
        }
        let got = c.run_stream(ops);
        assert_eq!(got.len(), expected.len(), "{kind:?}");
        for (i, (g, w)) in got.iter().zip(&expected).enumerate() {
            assert_eq!(g, w, "{kind:?}: op {i}");
        }
    }
}
