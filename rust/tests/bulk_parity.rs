//! Bulk-vs-scalar oracle property (the batch-native pipeline's
//! correctness contract): for every one of the eight concurrent designs,
//! driving the bulk API with coordinator-shaped batches — mixed
//! upsert/accumulate/query/erase ops over a tiny universe, so batches
//! are full of duplicate keys — produces results identical to a scalar
//! twin table driven op-by-op, and both agree with a `HashMap` oracle
//! (the `coordinator_e2e` oracle pattern).

use std::collections::HashMap;

use warpspeed::coordinator::{Coordinator, CoordinatorConfig, Op, OpResult};
use warpspeed::prng::Xoshiro256pp;
use warpspeed::tables::{build_table, TableKind, UpsertOp, UpsertResult};
use warpspeed::workloads::keys::distinct_keys;

/// Op classes mirror `coordinator::exec`'s run splitting: a mixed batch
/// executes as maximal same-class runs, each dispatched as one bulk call.
#[derive(Clone, Copy, PartialEq)]
enum Class {
    Put,
    Add,
    Get,
    Del,
}

fn gen_batch(rng: &mut Xoshiro256pp, universe: &[u64], len: usize) -> Vec<(Class, u64, u64)> {
    (0..len)
        .map(|_| {
            let k = universe[rng.next_below(universe.len() as u64) as usize];
            match rng.next_below(4) {
                0 => (Class::Put, k, rng.next_below(1_000)),
                1 => (Class::Add, k, rng.next_below(100)),
                2 => (Class::Get, k, 0),
                _ => (Class::Del, k, 0),
            }
        })
        .collect()
}

#[test]
fn bulk_matches_scalar_oracle_for_all_eight_designs() {
    for kind in TableKind::CONCURRENT {
        let bulk_t = build_table(kind, 4096);
        let scalar_t = build_table(kind, 4096);
        let mut oracle: HashMap<u64, u64> = HashMap::new();
        let mut rng = Xoshiro256pp::new(0xB01C ^ kind as u64);
        let universe = distinct_keys(64, 0xB02C ^ kind as u64);
        for round in 0..40 {
            let batch = gen_batch(&mut rng, &universe, 256);
            // Split into maximal same-class runs, dispatch each as ONE
            // bulk call — exactly what the coordinator executor does.
            let mut s = 0;
            while s < batch.len() {
                let class = batch[s].0;
                let mut e = s + 1;
                while e < batch.len() && batch[e].0 == class {
                    e += 1;
                }
                let run = &batch[s..e];
                match class {
                    Class::Put | Class::Add => {
                        let op = if class == Class::Put {
                            UpsertOp::Overwrite
                        } else {
                            UpsertOp::AddAssign
                        };
                        let pairs: Vec<(u64, u64)> =
                            run.iter().map(|&(_, k, v)| (k, v)).collect();
                        let mut got: Vec<UpsertResult> = Vec::new();
                        bulk_t.upsert_bulk(&pairs, &op, &mut got);
                        assert_eq!(got.len(), pairs.len());
                        for (i, &(k, v)) in pairs.iter().enumerate() {
                            let want = scalar_t.upsert(k, v, &op);
                            assert_eq!(
                                got[i], want,
                                "{kind:?}: round {round} upsert #{i} key {k:#x}"
                            );
                            if class == Class::Put {
                                oracle.insert(k, v);
                            } else {
                                oracle
                                    .entry(k)
                                    .and_modify(|x| *x = x.wrapping_add(v))
                                    .or_insert(v);
                            }
                        }
                    }
                    Class::Get => {
                        let keys: Vec<u64> = run.iter().map(|&(_, k, _)| k).collect();
                        let mut got: Vec<Option<u64>> = Vec::new();
                        bulk_t.query_bulk(&keys, &mut got);
                        assert_eq!(got.len(), keys.len());
                        for (i, &k) in keys.iter().enumerate() {
                            assert_eq!(
                                got[i],
                                oracle.get(&k).copied(),
                                "{kind:?}: round {round} query #{i} key {k:#x}"
                            );
                            assert_eq!(got[i], scalar_t.query(k), "{kind:?}");
                        }
                    }
                    Class::Del => {
                        let keys: Vec<u64> = run.iter().map(|&(_, k, _)| k).collect();
                        let mut got: Vec<bool> = Vec::new();
                        bulk_t.erase_bulk(&keys, &mut got);
                        assert_eq!(got.len(), keys.len());
                        for (i, &k) in keys.iter().enumerate() {
                            let want = scalar_t.erase(k);
                            assert_eq!(
                                got[i], want,
                                "{kind:?}: round {round} erase #{i} key {k:#x}"
                            );
                            assert_eq!(got[i], oracle.remove(&k).is_some(), "{kind:?}");
                        }
                    }
                }
                s = e;
            }
        }
        // Final-state audit: bulk table ≡ oracle, no duplicate copies.
        assert_eq!(bulk_t.len(), oracle.len(), "{kind:?}");
        for &k in &universe {
            assert_eq!(bulk_t.query(k), oracle.get(&k).copied(), "{kind:?}");
            assert!(bulk_t.count_copies(k) <= 1, "{kind:?}: duplicate {k:#x}");
        }
    }
}

/// Multi-threaded bulk parity for the two designs that used to ride the
/// scalar fallback: threads churn bulk upserts + erases on disjoint key
/// ranges, per-op results must match the scalar-equivalent expectation,
/// and no key may ever hold more than one physical copy.
///
/// ChainingHT is stable (keys never move), so `count_copies(k) == 1` is
/// asserted THROUGHOUT the churn from a concurrent sampler. CuckooHT
/// moves keys (a raw table scan can catch a displacement mid-copy), so
/// its copy audit runs at the quiescent points; mid-churn each thread
/// instead asserts its locked queries return its own last-written value.
#[test]
fn concurrent_bulk_churn_keeps_single_copies_cuckoo_chaining() {
    for kind in [TableKind::Cuckoo, TableKind::Chaining] {
        let t = build_table(kind, 16 * 1024);
        let n_threads = 4;
        let per = 384;
        let all = distinct_keys(n_threads * per, 0xAB5 ^ kind as u64);
        let stable = kind == TableKind::Chaining;
        std::thread::scope(|s| {
            for tid in 0..n_threads {
                let t = &t;
                let mine = &all[tid * per..(tid + 1) * per];
                s.spawn(move || {
                    for round in 0..4u64 {
                        let pairs: Vec<(u64, u64)> =
                            mine.iter().map(|&k| (k, k ^ round)).collect();
                        let mut ures: Vec<UpsertResult> = Vec::new();
                        for chunk in pairs.chunks(96) {
                            t.upsert_bulk(chunk, &UpsertOp::Overwrite, &mut ures);
                        }
                        for (i, &r) in ures.iter().enumerate() {
                            // Round 0 inserts everything; later rounds
                            // re-insert the erased odd half and update
                            // the surviving even half.
                            let want = if round == 0 || i % 2 == 1 {
                                UpsertResult::Inserted
                            } else {
                                UpsertResult::Updated
                            };
                            assert_eq!(r, want, "{kind:?} round {round} upsert #{i}");
                        }
                        for (i, &k) in mine.iter().enumerate() {
                            if stable {
                                assert_eq!(
                                    t.count_copies(k),
                                    1,
                                    "{kind:?}: duplicate mid-churn"
                                );
                            } else {
                                assert_eq!(
                                    t.query(k),
                                    Some(k ^ round),
                                    "{kind:?} round {round} key #{i}"
                                );
                            }
                        }
                        let odd: Vec<u64> =
                            mine.iter().copied().skip(1).step_by(2).collect();
                        let mut eres: Vec<bool> = Vec::new();
                        for chunk in odd.chunks(96) {
                            t.erase_bulk(chunk, &mut eres);
                        }
                        assert!(
                            eres.iter().all(|&e| e),
                            "{kind:?} round {round}: bulk erase missed an own key"
                        );
                    }
                });
            }
        });
        // Quiescent audit: even keys survive with exactly one copy, odd
        // keys are gone without residue.
        for (i, &k) in all.iter().enumerate() {
            let i_in_range = i % per;
            if i_in_range % 2 == 0 {
                assert_eq!(t.query(k), Some(k ^ 3), "{kind:?}: survivor #{i}");
                assert_eq!(t.count_copies(k), 1, "{kind:?}: duplicate #{i}");
            } else {
                assert_eq!(t.query(k), None, "{kind:?}: zombie #{i}");
                assert_eq!(t.count_copies(k), 0, "{kind:?}: residue #{i}");
            }
        }
    }
}

/// Persistent-pool lifecycle: hundreds of batches flow through the same
/// long-lived workers with results in arrival order, for the two newly
/// bulk-native designs, and dropping the coordinator joins the pool
/// without hanging.
#[test]
fn persistent_pool_ordering_across_batches_and_clean_shutdown() {
    for kind in [TableKind::Cuckoo, TableKind::Chaining] {
        let c = Coordinator::new(CoordinatorConfig {
            kind,
            total_slots: 16 * 1024,
            n_shards: 4,
            n_workers: 3,
            max_batch: 32,
        });
        let ks = distinct_keys(256, 0x9D0 ^ kind as u64);
        for round in 0..3u64 {
            let mut ops = Vec::new();
            for (i, &k) in ks.iter().enumerate() {
                ops.push(Op::Upsert(k, round * 1000 + i as u64));
            }
            for &k in &ks {
                ops.push(Op::Query(k));
            }
            ops.extend(ks.iter().map(|&k| Op::Erase(k)));
            let r = c.run_stream(ops); // max_batch 32 → 24 pipelined batches
            assert_eq!(r.len(), 768, "{kind:?}");
            for (i, res) in r[..256].iter().enumerate() {
                assert_eq!(*res, OpResult::Upserted(true), "{kind:?} r{round} up {i}");
            }
            for (i, res) in r[256..512].iter().enumerate() {
                assert_eq!(
                    *res,
                    OpResult::Value(Some(round * 1000 + i as u64)),
                    "{kind:?} r{round} q {i}"
                );
            }
            for (i, res) in r[512..].iter().enumerate() {
                assert_eq!(*res, OpResult::Erased(true), "{kind:?} r{round} del {i}");
            }
        }
        assert_eq!(
            c.ops_executed
                .load(std::sync::atomic::Ordering::Relaxed),
            3 * 768
        );
        drop(c); // graceful shutdown: disconnect channels, join workers
    }
}

/// The same property served end-to-end through the coordinator's
/// batch-native executor (batcher → shard partition → run split → bulk
/// dispatch), for every concurrent design.
#[test]
fn coordinator_bulk_dispatch_matches_oracle_for_all_designs() {
    for kind in TableKind::CONCURRENT {
        let c = Coordinator::new(CoordinatorConfig {
            kind,
            total_slots: 16 * 1024,
            n_shards: 4,
            n_workers: 2,
            max_batch: 128,
        });
        let ks = distinct_keys(64, 0xC0DE ^ kind as u64);
        let mut oracle: HashMap<u64, u64> = HashMap::new();
        let mut rng = Xoshiro256pp::new(0xC1DE ^ kind as u64);
        let mut ops = Vec::new();
        let mut expected = Vec::new();
        for _ in 0..4_000 {
            let k = ks[rng.next_below(64) as usize];
            match rng.next_below(4) {
                0 => {
                    let v = rng.next_below(1_000);
                    ops.push(Op::Upsert(k, v));
                    let was_new = oracle.insert(k, v).is_none();
                    expected.push(OpResult::Upserted(was_new));
                }
                1 => {
                    let v = rng.next_below(100);
                    ops.push(Op::UpsertAdd(k, v));
                    match oracle.get_mut(&k) {
                        Some(x) => {
                            *x = x.wrapping_add(v);
                            expected.push(OpResult::Upserted(false));
                        }
                        None => {
                            oracle.insert(k, v);
                            expected.push(OpResult::Upserted(true));
                        }
                    }
                }
                2 => {
                    ops.push(Op::Query(k));
                    expected.push(OpResult::Value(oracle.get(&k).copied()));
                }
                _ => {
                    ops.push(Op::Erase(k));
                    expected.push(OpResult::Erased(oracle.remove(&k).is_some()));
                }
            }
        }
        let got = c.run_stream(ops);
        assert_eq!(got.len(), expected.len(), "{kind:?}");
        for (i, (g, w)) in got.iter().zip(&expected).enumerate() {
            assert_eq!(g, w, "{kind:?}: op {i}");
        }
    }
}
