//! Bulk-vs-scalar oracle property (the batch-native pipeline's
//! correctness contract): for every one of the eight concurrent designs,
//! driving the bulk API with coordinator-shaped batches — mixed
//! upsert/accumulate/query/erase ops over a tiny universe, so batches
//! are full of duplicate keys — produces results identical to a scalar
//! twin table driven op-by-op, and both agree with a `HashMap` oracle
//! (the `coordinator_e2e` oracle pattern).

use std::collections::HashMap;

use warpspeed::coordinator::{Coordinator, CoordinatorConfig, Op, OpResult};
use warpspeed::prng::Xoshiro256pp;
use warpspeed::tables::{
    build_table, ConcurrentMap, GrowableMap, GrowthPolicy, TableConfig, TableKind, UpsertOp,
    UpsertResult,
};
use warpspeed::workloads::keys::distinct_keys;

/// Op classes mirror `coordinator::exec`'s run splitting: a mixed batch
/// executes as maximal same-class runs, each dispatched as one bulk call.
#[derive(Clone, Copy, PartialEq)]
enum Class {
    Put,
    Add,
    Get,
    Del,
}

fn gen_batch(rng: &mut Xoshiro256pp, universe: &[u64], len: usize) -> Vec<(Class, u64, u64)> {
    (0..len)
        .map(|_| {
            let k = universe[rng.next_below(universe.len() as u64) as usize];
            match rng.next_below(4) {
                0 => (Class::Put, k, rng.next_below(1_000)),
                1 => (Class::Add, k, rng.next_below(100)),
                2 => (Class::Get, k, 0),
                _ => (Class::Del, k, 0),
            }
        })
        .collect()
}

#[test]
fn bulk_matches_scalar_oracle_for_all_eight_designs() {
    for kind in TableKind::CONCURRENT {
        let bulk_t = build_table(kind, 4096);
        let scalar_t = build_table(kind, 4096);
        let mut oracle: HashMap<u64, u64> = HashMap::new();
        let mut rng = Xoshiro256pp::new(0xB01C ^ kind as u64);
        let universe = distinct_keys(64, 0xB02C ^ kind as u64);
        for round in 0..40 {
            let batch = gen_batch(&mut rng, &universe, 256);
            // Split into maximal same-class runs, dispatch each as ONE
            // bulk call — exactly what the coordinator executor does.
            let mut s = 0;
            while s < batch.len() {
                let class = batch[s].0;
                let mut e = s + 1;
                while e < batch.len() && batch[e].0 == class {
                    e += 1;
                }
                let run = &batch[s..e];
                match class {
                    Class::Put | Class::Add => {
                        let op = if class == Class::Put {
                            UpsertOp::Overwrite
                        } else {
                            UpsertOp::AddAssign
                        };
                        let pairs: Vec<(u64, u64)> =
                            run.iter().map(|&(_, k, v)| (k, v)).collect();
                        let mut got: Vec<UpsertResult> = Vec::new();
                        bulk_t.upsert_bulk(&pairs, &op, &mut got);
                        assert_eq!(got.len(), pairs.len());
                        for (i, &(k, v)) in pairs.iter().enumerate() {
                            let want = scalar_t.upsert(k, v, &op);
                            assert_eq!(
                                got[i], want,
                                "{kind:?}: round {round} upsert #{i} key {k:#x}"
                            );
                            if class == Class::Put {
                                oracle.insert(k, v);
                            } else {
                                oracle
                                    .entry(k)
                                    .and_modify(|x| *x = x.wrapping_add(v))
                                    .or_insert(v);
                            }
                        }
                    }
                    Class::Get => {
                        let keys: Vec<u64> = run.iter().map(|&(_, k, _)| k).collect();
                        let mut got: Vec<Option<u64>> = Vec::new();
                        bulk_t.query_bulk(&keys, &mut got);
                        assert_eq!(got.len(), keys.len());
                        for (i, &k) in keys.iter().enumerate() {
                            assert_eq!(
                                got[i],
                                oracle.get(&k).copied(),
                                "{kind:?}: round {round} query #{i} key {k:#x}"
                            );
                            assert_eq!(got[i], scalar_t.query(k), "{kind:?}");
                        }
                    }
                    Class::Del => {
                        let keys: Vec<u64> = run.iter().map(|&(_, k, _)| k).collect();
                        let mut got: Vec<bool> = Vec::new();
                        bulk_t.erase_bulk(&keys, &mut got);
                        assert_eq!(got.len(), keys.len());
                        for (i, &k) in keys.iter().enumerate() {
                            let want = scalar_t.erase(k);
                            assert_eq!(
                                got[i], want,
                                "{kind:?}: round {round} erase #{i} key {k:#x}"
                            );
                            assert_eq!(got[i], oracle.remove(&k).is_some(), "{kind:?}");
                        }
                    }
                }
                s = e;
            }
        }
        // Final-state audit: bulk table ≡ oracle, no duplicate copies.
        assert_eq!(bulk_t.len(), oracle.len(), "{kind:?}");
        for &k in &universe {
            assert_eq!(bulk_t.query(k), oracle.get(&k).copied(), "{kind:?}");
            assert!(bulk_t.count_copies(k) <= 1, "{kind:?}: duplicate {k:#x}");
        }
    }
}

/// Multi-threaded bulk parity for the two designs that used to ride the
/// scalar fallback: threads churn bulk upserts + erases on disjoint key
/// ranges, per-op results must match the scalar-equivalent expectation,
/// and no key may ever hold more than one physical copy.
///
/// ChainingHT is stable (keys never move), so `count_copies(k) == 1` is
/// asserted THROUGHOUT the churn from a concurrent sampler. CuckooHT
/// moves keys (a raw table scan can catch a displacement mid-copy), so
/// its copy audit runs at the quiescent points; mid-churn each thread
/// instead asserts its locked queries return its own last-written value.
#[test]
fn concurrent_bulk_churn_keeps_single_copies_cuckoo_chaining() {
    for kind in [TableKind::Cuckoo, TableKind::Chaining] {
        let t = build_table(kind, 16 * 1024);
        let n_threads = 4;
        let per = 384;
        let all = distinct_keys(n_threads * per, 0xAB5 ^ kind as u64);
        let stable = kind == TableKind::Chaining;
        std::thread::scope(|s| {
            for tid in 0..n_threads {
                let t = &t;
                let mine = &all[tid * per..(tid + 1) * per];
                s.spawn(move || {
                    for round in 0..4u64 {
                        let pairs: Vec<(u64, u64)> =
                            mine.iter().map(|&k| (k, k ^ round)).collect();
                        let mut ures: Vec<UpsertResult> = Vec::new();
                        for chunk in pairs.chunks(96) {
                            t.upsert_bulk(chunk, &UpsertOp::Overwrite, &mut ures);
                        }
                        for (i, &r) in ures.iter().enumerate() {
                            // Round 0 inserts everything; later rounds
                            // re-insert the erased odd half and update
                            // the surviving even half.
                            let want = if round == 0 || i % 2 == 1 {
                                UpsertResult::Inserted
                            } else {
                                UpsertResult::Updated
                            };
                            assert_eq!(r, want, "{kind:?} round {round} upsert #{i}");
                        }
                        for (i, &k) in mine.iter().enumerate() {
                            if stable {
                                assert_eq!(
                                    t.count_copies(k),
                                    1,
                                    "{kind:?}: duplicate mid-churn"
                                );
                            } else {
                                assert_eq!(
                                    t.query(k),
                                    Some(k ^ round),
                                    "{kind:?} round {round} key #{i}"
                                );
                            }
                        }
                        let odd: Vec<u64> =
                            mine.iter().copied().skip(1).step_by(2).collect();
                        let mut eres: Vec<bool> = Vec::new();
                        for chunk in odd.chunks(96) {
                            t.erase_bulk(chunk, &mut eres);
                        }
                        assert!(
                            eres.iter().all(|&e| e),
                            "{kind:?} round {round}: bulk erase missed an own key"
                        );
                    }
                });
            }
        });
        // Quiescent audit: even keys survive with exactly one copy, odd
        // keys are gone without residue.
        for (i, &k) in all.iter().enumerate() {
            let i_in_range = i % per;
            if i_in_range % 2 == 0 {
                assert_eq!(t.query(k), Some(k ^ 3), "{kind:?}: survivor #{i}");
                assert_eq!(t.count_copies(k), 1, "{kind:?}: duplicate #{i}");
            } else {
                assert_eq!(t.query(k), None, "{kind:?}: zombie #{i}");
                assert_eq!(t.count_copies(k), 0, "{kind:?}: residue #{i}");
            }
        }
    }
}

/// Persistent-pool lifecycle: hundreds of batches flow through the same
/// long-lived workers with results in arrival order, for the two newly
/// bulk-native designs, and dropping the coordinator joins the pool
/// without hanging.
#[test]
fn persistent_pool_ordering_across_batches_and_clean_shutdown() {
    for kind in [TableKind::Cuckoo, TableKind::Chaining] {
        let c = Coordinator::new(CoordinatorConfig {
            kind,
            total_slots: 16 * 1024,
            n_shards: 4,
            n_workers: 3,
            max_batch: 32,
            growth: None,
            reshard: None,
            hotkey: None,
        });
        let ks = distinct_keys(256, 0x9D0 ^ kind as u64);
        for round in 0..3u64 {
            let mut ops = Vec::new();
            for (i, &k) in ks.iter().enumerate() {
                ops.push(Op::Upsert(k, round * 1000 + i as u64));
            }
            for &k in &ks {
                ops.push(Op::Query(k));
            }
            ops.extend(ks.iter().map(|&k| Op::Erase(k)));
            let r = c.run_stream(ops); // max_batch 32 → 24 pipelined batches
            assert_eq!(r.len(), 768, "{kind:?}");
            for (i, res) in r[..256].iter().enumerate() {
                assert_eq!(*res, OpResult::Upserted(true), "{kind:?} r{round} up {i}");
            }
            for (i, res) in r[256..512].iter().enumerate() {
                assert_eq!(
                    *res,
                    OpResult::Value(Some(round * 1000 + i as u64)),
                    "{kind:?} r{round} q {i}"
                );
            }
            for (i, res) in r[512..].iter().enumerate() {
                assert_eq!(*res, OpResult::Erased(true), "{kind:?} r{round} del {i}");
            }
        }
        assert_eq!(
            c.ops_executed
                .load(std::sync::atomic::Ordering::Relaxed),
            3 * 768
        );
        drop(c); // graceful shutdown: disconnect channels, join workers
    }
}

/// The same property served end-to-end through the coordinator's
/// batch-native executor (batcher → shard partition → run split → bulk
/// dispatch), for every concurrent design.
#[test]
fn coordinator_bulk_dispatch_matches_oracle_for_all_designs() {
    for kind in TableKind::CONCURRENT {
        let c = Coordinator::new(CoordinatorConfig {
            kind,
            total_slots: 16 * 1024,
            n_shards: 4,
            n_workers: 2,
            max_batch: 128,
            growth: None,
            reshard: None,
            hotkey: None,
        });
        let ks = distinct_keys(64, 0xC0DE ^ kind as u64);
        let mut oracle: HashMap<u64, u64> = HashMap::new();
        let mut rng = Xoshiro256pp::new(0xC1DE ^ kind as u64);
        let mut ops = Vec::new();
        let mut expected = Vec::new();
        for _ in 0..4_000 {
            let k = ks[rng.next_below(64) as usize];
            match rng.next_below(4) {
                0 => {
                    let v = rng.next_below(1_000);
                    ops.push(Op::Upsert(k, v));
                    let was_new = oracle.insert(k, v).is_none();
                    expected.push(OpResult::Upserted(was_new));
                }
                1 => {
                    let v = rng.next_below(100);
                    ops.push(Op::UpsertAdd(k, v));
                    match oracle.get_mut(&k) {
                        Some(x) => {
                            *x = x.wrapping_add(v);
                            expected.push(OpResult::Upserted(false));
                        }
                        None => {
                            oracle.insert(k, v);
                            expected.push(OpResult::Upserted(true));
                        }
                    }
                }
                2 => {
                    ops.push(Op::Query(k));
                    expected.push(OpResult::Value(oracle.get(&k).copied()));
                }
                _ => {
                    ops.push(Op::Erase(k));
                    expected.push(OpResult::Erased(oracle.remove(&k).is_some()));
                }
            }
        }
        let got = c.run_stream(ops);
        assert_eq!(got.len(), expected.len(), "{kind:?}");
        for (i, (g, w)) in got.iter().zip(&expected).enumerate() {
            assert_eq!(g, w, "{kind:?}: op {i}");
        }
    }
}

/// Grow-under-churn parity: a growable bulk table and a growable scalar
/// twin run the same insert-heavy mixed stream (upserts/queries/erases
/// over a universe 3× the nominal capacity, interleaved with bounded
/// migration steps) through at least one full 2× migration. Every per-op
/// result must match, zero ops may be Rejected/Full, and stable designs
/// keep `count_copies == 1` for live keys throughout.
#[test]
fn growable_bulk_parity_across_a_full_migration() {
    for kind in TableKind::CONCURRENT {
        let mk = || {
            GrowableMap::new(
                kind,
                TableConfig::for_kind(kind, 1024),
                GrowthPolicy {
                    migration_batch: 8,
                    ..Default::default()
                },
            )
        };
        let bulk_t = mk();
        let scalar_t = mk();
        let stable = bulk_t.is_stable();
        let nominal = bulk_t.capacity();
        let universe = distinct_keys(nominal * 3, 0x6F0 ^ kind as u64);
        let mut oracle: HashMap<u64, u64> = HashMap::new();
        let mut rng = Xoshiro256pp::new(0x6F1 ^ kind as u64);
        let mut cursor = 0usize; // insert frontier over the universe
        for round in 0..120 {
            match rng.next_below(8) {
                // Insert-heavy: 6/8 of rounds push a fresh batch.
                0..=5 => {
                    let n = (universe.len() - cursor).min(96);
                    if n == 0 {
                        continue;
                    }
                    let pairs: Vec<(u64, u64)> = universe[cursor..cursor + n]
                        .iter()
                        .map(|&k| (k, k ^ round))
                        .collect();
                    cursor += n;
                    let mut got = Vec::new();
                    bulk_t.upsert_bulk(&pairs, &UpsertOp::Overwrite, &mut got);
                    for (i, &(k, v)) in pairs.iter().enumerate() {
                        let want = scalar_t.upsert(k, v, &UpsertOp::Overwrite);
                        assert_ne!(got[i], UpsertResult::Full, "{kind:?} round {round}");
                        assert_eq!(got[i], want, "{kind:?} round {round} upsert #{i}");
                        oracle.insert(k, v);
                    }
                }
                6 => {
                    let ks: Vec<u64> = (0..64)
                        .map(|_| universe[rng.next_below(universe.len() as u64) as usize])
                        .collect();
                    let mut got = Vec::new();
                    bulk_t.query_bulk(&ks, &mut got);
                    for (i, &k) in ks.iter().enumerate() {
                        assert_eq!(got[i], oracle.get(&k).copied(), "{kind:?} round {round} q{i}");
                        assert_eq!(got[i], scalar_t.query(k), "{kind:?} round {round} q{i}");
                    }
                }
                _ => {
                    let ks: Vec<u64> = (0..48)
                        .map(|_| universe[rng.next_below(universe.len() as u64) as usize])
                        .collect();
                    let mut got = Vec::new();
                    bulk_t.erase_bulk(&ks, &mut got);
                    for (i, &k) in ks.iter().enumerate() {
                        let want = scalar_t.erase(k);
                        assert_eq!(got[i], want, "{kind:?} round {round} erase #{i}");
                        assert_eq!(got[i], oracle.remove(&k).is_some(), "{kind:?}");
                    }
                }
            }
            // Interleave bounded migration steps with the traffic, like
            // the coordinator's workers; twins may migrate at different
            // times — parity must hold regardless.
            bulk_t.drive_migration(8);
            scalar_t.drive_migration(16);
            if stable && round % 10 == 0 {
                for (&k, &v) in oracle.iter().take(24) {
                    assert_eq!(bulk_t.count_copies(k), 1, "{kind:?}: duplicate {k:#x}");
                    assert_eq!(bulk_t.query(k), Some(v), "{kind:?}: lost {k:#x}");
                }
            }
        }
        assert!(bulk_t.quiesce_migration(), "{kind:?}: migration pinned");
        assert!(scalar_t.quiesce_migration(), "{kind:?}: migration pinned");
        assert!(
            bulk_t.grow_events() >= 1 && bulk_t.capacity() >= nominal * 2,
            "{kind:?}: the churn must drive at least one full 2× growth \
             (capacity {} from {nominal})",
            bulk_t.capacity()
        );
        assert_eq!(bulk_t.len(), oracle.len(), "{kind:?}");
        for (&k, &v) in &oracle {
            assert_eq!(bulk_t.query(k), Some(v), "{kind:?}");
            assert!(bulk_t.count_copies(k) <= 1, "{kind:?}: duplicate {k:#x}");
        }
    }
}

/// Concurrent grow-under-churn for stable designs: threads churn bulk
/// upserts/queries/erases on disjoint key ranges across a live
/// migration; `count_copies == 1` is asserted for the checking thread's
/// own live keys THROUGHOUT, and zero Full results may surface.
#[test]
fn growable_concurrent_churn_parity_for_stable_designs() {
    for kind in [TableKind::P2Meta, TableKind::Chaining] {
        let t = std::sync::Arc::new(GrowableMap::new(
            kind,
            TableConfig::for_kind(kind, 2048),
            GrowthPolicy {
                migration_batch: 8,
                ..Default::default()
            },
        ));
        let n_threads = 4;
        let per = (t.capacity() * 5 / 2) / n_threads;
        let all = distinct_keys(n_threads * per, 0x6F5 ^ kind as u64);
        std::thread::scope(|s| {
            for tid in 0..n_threads {
                let t = std::sync::Arc::clone(&t);
                let mine = &all[tid * per..(tid + 1) * per];
                s.spawn(move || {
                    for round in 0..3u64 {
                        let mut ures: Vec<UpsertResult> = Vec::new();
                        for chunk in mine.chunks(96) {
                            let pairs: Vec<(u64, u64)> =
                                chunk.iter().map(|&k| (k, k ^ round)).collect();
                            t.upsert_bulk(&pairs, &UpsertOp::Overwrite, &mut ures);
                            t.drive_migration(2);
                        }
                        assert!(
                            ures.iter().all(|&r| r != UpsertResult::Full),
                            "{kind:?} round {round}: Full on a growable table"
                        );
                        for &k in mine.iter().step_by(13) {
                            assert_eq!(
                                t.count_copies(k),
                                1,
                                "{kind:?} round {round}: duplicate mid-migration"
                            );
                            assert_eq!(t.query(k), Some(k ^ round), "{kind:?} round {round}");
                        }
                        let odd: Vec<u64> = mine.iter().copied().skip(1).step_by(2).collect();
                        let mut eres: Vec<bool> = Vec::new();
                        for chunk in odd.chunks(96) {
                            t.erase_bulk(chunk, &mut eres);
                        }
                        assert!(
                            eres.iter().all(|&e| e),
                            "{kind:?} round {round}: erase missed an own key"
                        );
                    }
                });
            }
        });
        assert!(t.quiesce_migration(), "{kind:?}: migration pinned");
        assert!(t.grow_events() >= 1, "{kind:?}: churn at 2.5× nominal must grow");
        for (i, &k) in all.iter().enumerate() {
            if (i % per) % 2 == 0 {
                assert_eq!(t.query(k), Some(k ^ 2), "{kind:?}: survivor #{i}");
                assert_eq!(t.count_copies(k), 1, "{kind:?}: duplicate #{i}");
            } else {
                assert_eq!(t.query(k), None, "{kind:?}: zombie #{i}");
                assert_eq!(t.count_copies(k), 0, "{kind:?}: residue #{i}");
            }
        }
    }
}

/// Shrink-under-churn parity: the growable twins run an erase-heavy
/// mixed stream through at least one full ½× compaction — the exact
/// mirror of `growable_bulk_parity_across_a_full_migration`. Every
/// per-op result must match the scalar twin and the oracle, stable
/// designs keep `count_copies == 1` for live keys throughout, and both
/// twins end at a compacted capacity (twins may shrink at different
/// rounds — parity must hold regardless).
#[test]
fn growable_bulk_parity_across_a_full_compaction() {
    for kind in TableKind::CONCURRENT {
        let mk = || {
            GrowableMap::new(
                kind,
                TableConfig::for_kind(kind, 1024),
                GrowthPolicy {
                    migration_batch: 8,
                    shrink_below: 0.3,
                    ..Default::default()
                },
            )
        };
        let bulk_t = mk();
        let scalar_t = mk();
        let stable = bulk_t.is_stable();
        let nominal = bulk_t.capacity();
        let universe = distinct_keys(nominal * 5 / 2, 0x6F8 ^ kind as u64);
        let mut oracle: HashMap<u64, u64> = HashMap::new();
        // Heat: fill 2.5× nominal through the bulk/scalar pair (growth
        // machinery already parity-tested; keep this phase terse).
        for chunk in universe.chunks(96) {
            let pairs: Vec<(u64, u64)> = chunk.iter().map(|&k| (k, k ^ 9)).collect();
            let mut got = Vec::new();
            bulk_t.upsert_bulk(&pairs, &UpsertOp::Overwrite, &mut got);
            for (i, &(k, v)) in pairs.iter().enumerate() {
                assert_eq!(got[i], scalar_t.upsert(k, v, &UpsertOp::Overwrite), "{kind:?}");
                assert_ne!(got[i], UpsertResult::Full, "{kind:?}: heat phase rejected");
                oracle.insert(k, v);
            }
        }
        assert!(bulk_t.quiesce_migration() && scalar_t.quiesce_migration(), "{kind:?}");
        let peak = bulk_t.capacity();
        assert!(peak >= nominal * 2, "{kind:?}: heat never grew ({peak} from {nominal})");
        // Cool: erase-heavy mixed rounds walk the load under the 0.3
        // watermark; compactions start mid-stream and interleave with
        // the continuing traffic via bounded drive_migration steps.
        let mut rng = Xoshiro256pp::new(0x6F9 ^ kind as u64);
        let mut kill = 0usize; // erase frontier over the universe
        for round in 0..120u64 {
            match rng.next_below(8) {
                // Erase-heavy: 5/8 of rounds kill a fresh slice.
                0..=4 => {
                    let n = (universe.len().saturating_sub(kill)).min(64);
                    if n == 0 {
                        continue;
                    }
                    let ks: Vec<u64> = universe[kill..kill + n].to_vec();
                    kill += n;
                    let mut got = Vec::new();
                    bulk_t.erase_bulk(&ks, &mut got);
                    for (i, &k) in ks.iter().enumerate() {
                        let want = scalar_t.erase(k);
                        assert_eq!(got[i], want, "{kind:?} round {round} erase #{i}");
                        assert_eq!(got[i], oracle.remove(&k).is_some(), "{kind:?}");
                    }
                }
                5 => {
                    let ks: Vec<u64> = (0..48)
                        .map(|_| universe[rng.next_below(universe.len() as u64) as usize])
                        .collect();
                    let mut got = Vec::new();
                    bulk_t.query_bulk(&ks, &mut got);
                    for (i, &k) in ks.iter().enumerate() {
                        assert_eq!(got[i], oracle.get(&k).copied(), "{kind:?} round {round} q{i}");
                        assert_eq!(got[i], scalar_t.query(k), "{kind:?} round {round} q{i}");
                    }
                }
                _ => {
                    // A little live write traffic against surviving keys
                    // keeps the compaction honest (upserts land in the
                    // successor, merges see the pre-shrink value).
                    let ks: Vec<u64> = (0..24)
                        .map(|_| universe[rng.next_below(universe.len() as u64) as usize])
                        .collect();
                    let pairs: Vec<(u64, u64)> =
                        ks.iter().map(|&k| (k, k ^ round)).collect();
                    let mut got = Vec::new();
                    bulk_t.upsert_bulk(&pairs, &UpsertOp::Overwrite, &mut got);
                    for (i, &(k, v)) in pairs.iter().enumerate() {
                        let want = scalar_t.upsert(k, v, &UpsertOp::Overwrite);
                        assert_eq!(got[i], want, "{kind:?} round {round} upsert #{i}");
                        assert_ne!(got[i], UpsertResult::Full, "{kind:?}");
                        oracle.insert(k, v);
                    }
                }
            }
            bulk_t.drive_migration(8);
            scalar_t.drive_migration(16);
            if stable && round % 10 == 0 {
                for (&k, &v) in oracle.iter().take(16) {
                    assert_eq!(bulk_t.count_copies(k), 1, "{kind:?}: duplicate {k:#x}");
                    assert_eq!(bulk_t.query(k), Some(v), "{kind:?}: lost {k:#x}");
                }
            }
        }
        // Kill whatever the random rounds left, then drain.
        while kill < universe.len() {
            let n = (universe.len() - kill).min(96);
            let ks: Vec<u64> = universe[kill..kill + n].to_vec();
            kill += n;
            let mut got = Vec::new();
            bulk_t.erase_bulk(&ks, &mut got);
            for (i, &k) in ks.iter().enumerate() {
                assert_eq!(got[i], scalar_t.erase(k), "{kind:?}: final kill #{i}");
                oracle.remove(&k);
            }
        }
        assert!(bulk_t.quiesce_migration(), "{kind:?}: compaction pinned");
        assert!(scalar_t.quiesce_migration(), "{kind:?}: compaction pinned");
        assert!(
            bulk_t.shrink_events() >= 1,
            "{kind:?}: the cooldown never drove a ½× compaction"
        );
        assert!(
            bulk_t.capacity() < peak,
            "{kind:?}: capacity {} never fell from its peak {peak}",
            bulk_t.capacity()
        );
        assert_eq!(bulk_t.len(), oracle.len(), "{kind:?}");
        for (&k, &v) in &oracle {
            assert_eq!(bulk_t.query(k), Some(v), "{kind:?}");
            assert!(bulk_t.count_copies(k) <= 1, "{kind:?}: duplicate {k:#x}");
        }
    }
}

/// Colliding-key grouped-path coverage: a batch whose keys all share one
/// primary bucket (plus in-batch duplicates) exercises exactly the
/// grouped fast paths that pre-fill their output with sentinel values. A
/// skipped output slot would surface either as the debug-mode
/// written-slot assertion in the bulk helpers or as a parity mismatch
/// against the scalar twin here.
#[test]
fn grouped_path_covers_every_slot_for_colliding_keys() {
    for kind in TableKind::CONCURRENT {
        let bulk_t = build_table(kind, 2048);
        let scalar_t = build_table(kind, 2048);
        // Craft 6 distinct keys sharing the first key's primary bucket.
        let pool = distinct_keys(60_000, 0x7C0 ^ kind as u64);
        let b0 = bulk_t.primary_bucket(pool[0]);
        let colliding: Vec<u64> = pool
            .iter()
            .copied()
            .filter(|&k| bulk_t.primary_bucket(k) == b0)
            .take(6)
            .collect();
        assert!(
            colliding.len() >= 4,
            "{kind:?}: key pool too small to collide (got {})",
            colliding.len()
        );
        // Duplicate-laden batch: every key appears 2-3 times.
        let mut batch: Vec<u64> = Vec::new();
        for rep in 0..3 {
            for (i, &k) in colliding.iter().enumerate() {
                if rep < 2 || i % 2 == 0 {
                    batch.push(k);
                }
            }
        }
        let pairs: Vec<(u64, u64)> = batch
            .iter()
            .enumerate()
            .map(|(i, &k)| (k, i as u64))
            .collect();
        let mut got_u = Vec::new();
        bulk_t.upsert_bulk(&pairs, &UpsertOp::Overwrite, &mut got_u);
        assert_eq!(got_u.len(), pairs.len(), "{kind:?}: missing upsert results");
        for (i, &(k, v)) in pairs.iter().enumerate() {
            assert_eq!(
                got_u[i],
                scalar_t.upsert(k, v, &UpsertOp::Overwrite),
                "{kind:?}: colliding upsert #{i}"
            );
        }
        let mut got_q = Vec::new();
        bulk_t.query_bulk(&batch, &mut got_q);
        assert_eq!(got_q.len(), batch.len(), "{kind:?}: missing query results");
        for (i, &k) in batch.iter().enumerate() {
            assert_eq!(got_q[i], scalar_t.query(k), "{kind:?}: colliding query #{i}");
        }
        // Erase with duplicates: first hit erases, repeats report false.
        let mut got_e = Vec::new();
        bulk_t.erase_bulk(&batch, &mut got_e);
        assert_eq!(got_e.len(), batch.len(), "{kind:?}: missing erase results");
        for (i, &k) in batch.iter().enumerate() {
            assert_eq!(got_e[i], scalar_t.erase(k), "{kind:?}: colliding erase #{i}");
        }
    }
}

/// The bulk-vs-scalar parity oracle extended across a shard-count
/// split AND the merge back down: a `ShardedTable` driven through the
/// index-addressed bulk entry points (partitioned under the current
/// router, exactly as the coordinator executor does) must match a
/// scalar twin and the oracle while a split begun mid-stream migrates
/// interleaved with the batches — and again while the merge drains the
/// children back. Per-key order is preserved because a key never
/// changes parts within an epoch, and both twins rescale at the same
/// rounds.
#[test]
fn sharded_bulk_matches_scalar_across_a_split_merge_round_trip() {
    use warpspeed::coordinator::ShardedTable;
    for kind in [TableKind::Double, TableKind::Cuckoo, TableKind::Chaining] {
        let bulk_t = ShardedTable::new(kind, 8 * 1024, 2);
        let scalar_t = ShardedTable::new(kind, 8 * 1024, 2);
        let mut oracle: HashMap<u64, u64> = HashMap::new();
        let mut rng = Xoshiro256pp::new(0x5B11 ^ kind as u64);
        let universe = distinct_keys(96, 0x5B12 ^ kind as u64);
        for round in 0..45 {
            if round == 10 {
                assert!(bulk_t.split_shards(), "{kind:?}");
                assert!(scalar_t.split_shards(), "{kind:?}");
            }
            if round == 25 {
                // Both twins must have flipped epochs before the merge
                // can start (a merge refuses mid-split).
                assert!(bulk_t.quiesce_split(), "{kind:?}");
                assert!(scalar_t.quiesce_split(), "{kind:?}");
                assert!(bulk_t.merge_shards(), "{kind:?}");
                assert!(scalar_t.merge_shards(), "{kind:?}");
            }
            // A little bounded migration between batches, like the
            // coordinator's per-submit SplitMigrate/MergeMigrate jobs.
            for t in [&bulk_t, &scalar_t] {
                for pair in t.split_pairs_pending() {
                    t.drive_split(pair, 24);
                }
                for pair in t.merge_pairs_pending() {
                    t.drive_merge(pair, 24);
                }
            }
            let batch = gen_batch(&mut rng, &universe, 192);
            let router = bulk_t.current_router();
            assert_eq!(router, scalar_t.current_router(), "{kind:?}: twins diverged");
            let mut parts: Vec<Vec<(Class, u64, u64)>> = vec![Vec::new(); router.n_shards()];
            for &item in &batch {
                parts[router.shard_of(item.1)].push(item);
            }
            for (idx, part) in parts.iter().enumerate() {
                let mut s = 0;
                while s < part.len() {
                    let class = part[s].0;
                    let mut e = s + 1;
                    while e < part.len() && part[e].0 == class {
                        e += 1;
                    }
                    let run = &part[s..e];
                    match class {
                        Class::Put | Class::Add => {
                            let op = if class == Class::Put {
                                UpsertOp::Overwrite
                            } else {
                                UpsertOp::AddAssign
                            };
                            let pairs: Vec<(u64, u64)> =
                                run.iter().map(|&(_, k, v)| (k, v)).collect();
                            let mut got: Vec<UpsertResult> = Vec::new();
                            bulk_t.upsert_bulk_on(idx, &pairs, &op, &mut got);
                            assert_eq!(got.len(), pairs.len());
                            for (i, &(k, v)) in pairs.iter().enumerate() {
                                let want = scalar_t.upsert(k, v, &op);
                                assert_eq!(
                                    got[i], want,
                                    "{kind:?}: round {round} shard {idx} upsert #{i}"
                                );
                                if class == Class::Put {
                                    oracle.insert(k, v);
                                } else {
                                    oracle
                                        .entry(k)
                                        .and_modify(|x| *x = x.wrapping_add(v))
                                        .or_insert(v);
                                }
                            }
                        }
                        Class::Get => {
                            let keys: Vec<u64> = run.iter().map(|&(_, k, _)| k).collect();
                            let mut got: Vec<Option<u64>> = Vec::new();
                            bulk_t.query_bulk_on(idx, &keys, &mut got);
                            assert_eq!(got.len(), keys.len());
                            for (i, &k) in keys.iter().enumerate() {
                                assert_eq!(
                                    got[i],
                                    oracle.get(&k).copied(),
                                    "{kind:?}: round {round} shard {idx} query #{i}"
                                );
                                assert_eq!(got[i], scalar_t.query(k), "{kind:?}");
                            }
                        }
                        Class::Del => {
                            let keys: Vec<u64> = run.iter().map(|&(_, k, _)| k).collect();
                            let mut got: Vec<bool> = Vec::new();
                            bulk_t.erase_bulk_on(idx, &keys, &mut got);
                            assert_eq!(got.len(), keys.len());
                            for (i, &k) in keys.iter().enumerate() {
                                let want = scalar_t.erase(k);
                                assert_eq!(
                                    got[i], want,
                                    "{kind:?}: round {round} shard {idx} erase #{i}"
                                );
                                assert_eq!(got[i], oracle.remove(&k).is_some(), "{kind:?}");
                            }
                        }
                    }
                    s = e;
                }
            }
        }
        assert!(bulk_t.quiesce_merge(), "{kind:?}: bulk twin merge never completed");
        assert!(scalar_t.quiesce_merge(), "{kind:?}: scalar twin merge never completed");
        assert_eq!(bulk_t.n_shards(), 2, "{kind:?}: round trip must land at 2 shards");
        assert_eq!(bulk_t.epoch(), 2, "{kind:?}: split + merge = two epoch advances");
        assert_eq!(bulk_t.split_events(), 1, "{kind:?}");
        assert_eq!(bulk_t.merge_events(), 1, "{kind:?}");
        assert_eq!(bulk_t.len(), oracle.len(), "{kind:?}: keys lost or duplicated");
        for &k in &universe {
            assert_eq!(bulk_t.query(k), oracle.get(&k).copied(), "{kind:?}");
            assert_eq!(scalar_t.query(k), oracle.get(&k).copied(), "{kind:?}");
        }
    }
}
