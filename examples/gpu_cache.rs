//! GPU caching workload (paper §6.6): device hash table caching a
//! host-side store with FIFO eviction, sweeping the cache-to-data ratio.
//!
//! Run: `cargo run --release --example gpu_cache [data_size]`

use std::sync::Arc;

use warpspeed::apps::caching::{GpuCache, HostStore};
use warpspeed::tables::{build_table, TableKind};
use warpspeed::workloads::keys::{distinct_keys, UniverseDraws};

fn main() {
    let data_size: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(100_000);
    let n_queries = data_size * 4;
    let data = distinct_keys(data_size, 0xDA7A);
    println!("dataset: {data_size} keys; {n_queries} uniform queries per point\n");
    println!("{:>7} {:>14} {:>10} {:>9} {:>12}", "ratio%", "table", "Mops/s", "hit-rate", "evictions");
    for ratio in [0.05, 0.10, 0.25, 0.50, 0.70] {
        for kind in [
            TableKind::P2Meta,
            TableKind::IcebergMeta,
            TableKind::Double,
            TableKind::Chaining,
            TableKind::Cuckoo,
        ] {
            let table = build_table(kind, (data_size as f64 * ratio) as usize + 64);
            let store = HostStore::new(data.iter().map(|&k| (k, k ^ 0xCAFE)));
            let Some(mut cache) = GpuCache::new(Arc::clone(&table), store) else {
                println!(
                    "{:>7.0} {:>14} {:>10} (cannot run: unstable design)",
                    ratio * 100.0,
                    kind.paper_name(),
                    "-"
                );
                continue;
            };
            let mut draws = UniverseDraws::new(&data, 0xD1CE);
            let start = std::time::Instant::now();
            for _ in 0..n_queries {
                let k = draws.next_key();
                let v = cache.get(k).expect("all keys exist in the store");
                debug_assert_eq!(v, k ^ 0xCAFE);
            }
            let dt = start.elapsed().as_secs_f64();
            println!(
                "{:>7.0} {:>14} {:>10.2} {:>8.1}% {:>12}",
                ratio * 100.0,
                kind.paper_name(),
                n_queries as f64 / dt / 1e6,
                cache.hit_rate() * 100.0,
                cache.evictions
            );
        }
        println!();
    }
}
