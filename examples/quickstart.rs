//! Quickstart: build a concurrent table, exercise the paper's API
//! (upsert / query / erase / compound upserts), run concurrent writers,
//! and finish with the three-layer AOT path (PJRT bulk query) if
//! artifacts are present.
//!
//! Run: `cargo run --release --example quickstart`

use std::sync::Arc;
use std::thread;

use warpspeed::prng::Xoshiro256pp;
use warpspeed::runtime::{artifacts_dir, BulkQueryEngine};
use warpspeed::tables::kernel_table::KernelTable;
use warpspeed::tables::{build_table, TableKind, UpsertOp, UpsertResult};

fn main() {
    // 1. Build: pick any of the paper's eight designs.
    let table = build_table(TableKind::P2Meta, 1 << 16);
    println!("built {} with capacity {}", table.name(), table.capacity());

    // 2. The API surface (paper §5.1).
    assert_eq!(
        table.upsert(42, 1000, &UpsertOp::InsertIfUnique),
        UpsertResult::Inserted
    );
    assert_eq!(table.query(42), Some(1000));
    // Compound upsert: atomic accumulate (the k-mer-counting use case).
    table.upsert(42, 17, &UpsertOp::AddAssign);
    assert_eq!(table.query(42), Some(1017));
    // Custom merge callback: keep the max.
    let max_merge = |old: u64, new: u64| old.max(new);
    table.upsert(42, 500, &UpsertOp::Custom(&max_merge));
    assert_eq!(table.query(42), Some(1017));
    assert!(table.erase(42));
    println!("single-thread API: OK");

    // 3. Full concurrency: simultaneous inserts, queries, deletes.
    let writers = 4;
    let per = 10_000usize;
    let mut hs = Vec::new();
    for w in 0..writers {
        let t = Arc::clone(&table);
        hs.push(thread::spawn(move || {
            let mut rng = Xoshiro256pp::new(w as u64 + 1);
            for i in 0..per {
                let k = (w as u64 + 1) << 48 | i as u64 + 1;
                t.upsert(k, rng.next_u64() >> 1, &UpsertOp::Overwrite);
                if i % 3 == 0 {
                    std::hint::black_box(t.query(k));
                }
                if i % 7 == 0 {
                    t.erase(k);
                }
            }
        }));
    }
    for h in hs {
        h.join().unwrap();
    }
    println!(
        "concurrent phase: {} live keys, all probes consistent",
        table.len()
    );

    // 4. Three-layer path: snapshot → AOT Pallas kernel via PJRT.
    match BulkQueryEngine::load(&artifacts_dir()) {
        Ok(engine) => {
            let mut snap = KernelTable::new(engine.nb, engine.b);
            let mut rng = Xoshiro256pp::new(9);
            let mut keys = Vec::new();
            while keys.len() < 10_000 {
                let k = (rng.next_u64() as u32) | 1;
                if snap.insert(k, k ^ 0xAA55) {
                    keys.push(k);
                }
            }
            let results = engine.query_all(&snap, &keys).expect("bulk query");
            let hits = results.iter().filter(|r| r.is_some()).count();
            assert_eq!(hits, keys.len(), "AOT kernel must find every key");
            for (k, r) in keys.iter().zip(&results) {
                assert_eq!(*r, Some(k ^ 0xAA55));
            }
            println!("AOT PJRT bulk query: {hits}/{} found — parity OK", keys.len());
        }
        Err(e) => println!("AOT path skipped ({e:#}); run `make artifacts`"),
    }
    println!("quickstart complete");
}
