//! YCSB over the coordinator (paper §6.8 as a served workload): the
//! sharded coordinator executes batched Zipfian A/B/C streams, reporting
//! throughput per workload — the "database serving" shape of the paper's
//! evaluation, driven through the L3 router/batcher/executor stack.
//!
//! Run: `cargo run --release --example ycsb_server [universe_size]`

use warpspeed::coordinator::{default_workers, Coordinator, CoordinatorConfig, Op};
use warpspeed::tables::TableKind;
use warpspeed::workloads::keys::distinct_keys;
use warpspeed::workloads::ycsb::{Workload, YcsbOp, YcsbStream};

fn main() {
    let universe_size: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200_000);
    for kind in [TableKind::Double, TableKind::DoubleMeta, TableKind::P2Meta, TableKind::Chaining] {
        let coord = Coordinator::new(CoordinatorConfig {
            kind,
            total_slots: universe_size * 100 / 85,
            n_shards: 8,
            n_workers: default_workers(),
            max_batch: 4096,
            growth: None,
            reshard: None,
        });
        let universe = distinct_keys(universe_size, 0x4C5B);
        // Pre-load every key (paper setup).
        let start = std::time::Instant::now();
        coord.run_stream(universe.iter().map(|&k| Op::Upsert(k, k ^ 9)));
        let load_dt = start.elapsed().as_secs_f64();
        print!(
            "{:14} load {:7.2} Mops/s |",
            kind.paper_name(),
            universe.len() as f64 / load_dt / 1e6
        );
        for w in Workload::ALL {
            let mut stream = YcsbStream::new(&universe, w, 7);
            let n_ops = universe_size;
            let ops: Vec<Op> = (0..n_ops)
                .map(|_| match stream.next_op() {
                    YcsbOp::Read(k) => Op::Query(k),
                    YcsbOp::Update(k, v) => Op::Upsert(k, v),
                })
                .collect();
            let start = std::time::Instant::now();
            let results = coord.run_stream(ops);
            let dt = start.elapsed().as_secs_f64();
            assert_eq!(results.len(), n_ops);
            print!(" {}: {:7.2} Mops/s", w.name(), n_ops as f64 / dt / 1e6);
        }
        println!();
    }
}
