//! Sparse tensor contraction (paper §6.7): contract a NIPS-like synthetic
//! tensor with itself over mode 2 and modes (0,1,3), comparing the stable
//! fast path (lock-free in-place accumulation) against the CPU baseline.
//!
//! Run: `cargo run --release --example tensor_contraction [scale]`

use warpspeed::apps::sptc::{contract, contract_cpu_baseline, synthetic_nips};
use warpspeed::tables::{build_table, TableKind};

fn main() {
    let scale: f64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.08);
    let t = synthetic_nips(scale, 42);
    println!("tensor: dims {:?}, nnz {}", t.dims, t.nnz());

    for (label, cmodes) in [("1-mode (2)", vec![2usize]), ("3-mode (0,1,3)", vec![0, 1, 3])] {
        for kind in [TableKind::Double, TableKind::P2Meta, TableKind::Cuckoo] {
            let yt = build_table(kind, t.nnz() * 2 + 1024);
            let ot = build_table(kind, t.nnz() * 16 + 1024);
            let start = std::time::Instant::now();
            let r = contract(&t, &t, &cmodes, &cmodes, yt, ot);
            let dt = start.elapsed().as_secs_f64();
            println!(
                "{label:16} {:14} {dt:8.3}s  matches={:8}  fast={:8} slow={:8}",
                kind.paper_name(),
                r.matches,
                r.fast_path_adds,
                r.slow_path_upserts
            );
        }
        let start = std::time::Instant::now();
        let base = contract_cpu_baseline(&t, &t, &cmodes, &cmodes);
        let dt = start.elapsed().as_secs_f64();
        println!(
            "{label:16} {:14} {dt:8.3}s  output nnz={}",
            "SPARTA-like", base.len()
        );
        // Validate one design against the baseline checksum.
        let yt = build_table(TableKind::Double, t.nnz() * 2 + 1024);
        let ot = build_table(TableKind::Double, t.nnz() * 16 + 1024);
        let r = contract(&t, &t, &cmodes, &cmodes, yt, ot);
        let want: f64 = base.values().sum();
        let got = r.checksum();
        assert!(
            (got - want).abs() < 1e-6 * (1.0 + want.abs()),
            "{label}: checksum mismatch {got} vs {want}"
        );
        println!("{label:16} checksum parity vs baseline: OK\n");
    }
}
