//! k-mer counting — the genomics workload the paper's introduction uses
//! to motivate concurrent upserts ("genomics applications like de-novo
//! assembly and k-mer counting require upserts, a compound operation that
//! either inserts a new key or modifies its value").
//!
//! Synthetic reads are sheared from a random reference genome (so k-mers
//! genuinely repeat), then counted with `UpsertOp::AddAssign` from
//! multiple threads — every count lands atomically, no external
//! synchronization. Verified against a sequential HashMap count.
//!
//! Run: `cargo run --release --example kmer_counting [genome_len] [k]`

use std::collections::HashMap;
use std::sync::Arc;
use std::thread;

use warpspeed::prng::Xoshiro256pp;
use warpspeed::tables::{build_table, TableKind, UpsertOp};

/// Pack a DNA window (2 bits/base) into a u64 key; +1 avoids EMPTY.
fn pack_kmer(genome: &[u8], pos: usize, k: usize) -> u64 {
    let mut key = 0u64;
    for &b in &genome[pos..pos + k] {
        key = (key << 2) | b as u64;
    }
    key + 1
}

fn main() {
    let genome_len: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(2_000_000);
    let k: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(21);
    assert!(k <= 31, "k must fit 2k bits in a u64 key");

    // Repetitive reference genome: real genomes are full of repeats, and
    // repeats are what make counting an *upsert* (insert-or-increment)
    // workload. Concatenate random draws from a small motif library.
    let mut rng = Xoshiro256pp::new(0xD7A);
    let motif_len = 100;
    let motifs: Vec<Vec<u8>> = (0..64)
        .map(|_| (0..motif_len).map(|_| rng.next_below(4) as u8).collect())
        .collect();
    let mut genome: Vec<u8> = Vec::with_capacity(genome_len);
    while genome.len() < genome_len {
        genome.extend_from_slice(&motifs[rng.next_below(64) as usize]);
    }
    genome.truncate(genome_len);
    let n_kmers = genome_len - k + 1;
    println!("genome {genome_len} bp, k={k}, {n_kmers} k-mers");

    // Count concurrently: threads shear disjoint read ranges.
    let table = build_table(TableKind::IcebergMeta, n_kmers * 2);
    let genome = Arc::new(genome);
    let n_threads = 4;
    let start = std::time::Instant::now();
    let mut hs = Vec::new();
    for t in 0..n_threads {
        let table = Arc::clone(&table);
        let genome = Arc::clone(&genome);
        hs.push(thread::spawn(move || {
            let lo = t * n_kmers / n_threads;
            let hi = ((t + 1) * n_kmers / n_threads).min(n_kmers);
            for pos in lo..hi {
                let kmer = pack_kmer(&genome, pos, k);
                // The compound op: insert-or-increment, atomically.
                table.upsert(kmer, 1, &UpsertOp::AddAssign);
            }
        }));
    }
    for h in hs {
        h.join().unwrap();
    }
    let dt = start.elapsed().as_secs_f64();
    println!(
        "counted {n_kmers} k-mers in {dt:.3}s ({:.2} M upserts/s), {} distinct",
        n_kmers as f64 / dt / 1e6,
        table.len()
    );

    // Verify against a sequential oracle.
    let mut oracle: HashMap<u64, u64> = HashMap::new();
    for pos in 0..n_kmers {
        *oracle.entry(pack_kmer(&genome, pos, k)).or_insert(0) += 1;
    }
    assert_eq!(table.len(), oracle.len(), "distinct k-mer count mismatch");
    let mut max_kmer = (0u64, 0u64);
    for (&kmer, &count) in &oracle {
        let got = table.query(kmer).expect("k-mer lost");
        assert_eq!(got, count, "count mismatch for k-mer {kmer:#x}");
        if count > max_kmer.1 {
            max_kmer = (kmer, count);
        }
    }
    println!(
        "verified against sequential oracle: OK (hottest k-mer seen {}x)",
        max_kmer.1
    );
}
